"""Serve one of the assigned backbone architectures with batched greedy
decoding over its KV/SSM caches (smoke-scale configs on CPU; the same code
path the decode_32k / long_500k dry-run cells lower for the 256-chip mesh).

  PYTHONPATH=src python examples/serve_backbone.py --arch hymba-1.5b \
      [--batch 4 --prompt-len 32 --decode-steps 24]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.launch import step_fns as SF
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b",
                    choices=base.list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = base.get_arch(args.arch).SMOKE
    key = jax.random.PRNGKey(args.seed)
    params = api.init_model(key, cfg)
    B, P = args.batch, args.prompt_len
    max_len = P + args.decode_steps
    shape = (B, P, cfg.n_codebooks) if cfg.n_codebooks else (B, P)
    prompts = jax.random.randint(key, shape, 0, cfg.vocab)

    serve_step = jax.jit(SF.make_serve_step(cfg))
    caches = api.init_caches(cfg, B, max_len)

    t0 = time.time()
    tok = prompts[:, :1]
    for pos in range(P):  # prefill through the decode path
        tok, caches = serve_step(params, caches, prompts[:, pos:pos + 1],
                                 jnp.int32(pos))
    t_prefill = time.time() - t0

    out, t0 = [], time.time()
    for pos in range(P, max_len):
        tok, caches = serve_step(params, caches, tok, jnp.int32(pos))
        out.append(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"[serve] {args.arch} (smoke config): prefilled {P} tokens in "
          f"{t_prefill:.2f}s, decoded {args.decode_steps} in {t_decode:.2f}s "
          f"({args.decode_steps * B / max(t_decode, 1e-9):.1f} tok/s)")
    print(f"[serve] continuation[0]: {gen[0].reshape(-1)[:16].tolist()}")
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab)))


if __name__ == "__main__":
    main()
