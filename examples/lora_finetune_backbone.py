"""LoRA fine-tune an assigned backbone on a synthetic token stream with the
production train_step (Adam + grad clip + checkpoint/restart) — the same
function the dry-run lowers for the 512-chip mesh, here on the host devices.

  PYTHONPATH=src python examples/lora_finetune_backbone.py \
      --arch gemma2-27b --steps 30
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import base
from repro.data.tokens import synthetic_token_batches
from repro.launch import step_fns as SF
from repro.models import api
from repro.optim import adam_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b", choices=base.list_archs())
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/lora_ft_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = base.get_arch(args.arch).SMOKE
    key = jax.random.PRNGKey(args.seed)
    params = api.init_model(key, cfg)
    tr, _ = SF.split_trainable(params, "lora")
    n_tr = sum(x.size for x in jax.tree.leaves(tr))
    n_all = api.param_count(params)
    print(f"[lora-ft] {args.arch} smoke: {n_all:,} params, {n_tr:,} "
          f"trainable LoRA ({100 * n_tr / n_all:.2f}%)")

    opt = adam_init(tr)
    step_fn = jax.jit(SF.make_train_step(cfg, lr=args.lr, train_mode="lora"))
    ckpt = CheckpointManager(args.ckpt_dir, keep=1)

    losses = []
    t0 = time.time()
    for i, b in enumerate(synthetic_token_batches(
            cfg.vocab, args.batch, args.seq, args.steps, seed=args.seed,
            n_codebooks=cfg.n_codebooks)):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros((args.batch, cfg.n_patches,
                                          cfg.d_model))
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        if (i + 1) % 10 == 0:
            print(f"[lora-ft] step {i + 1:3d} loss {losses[-1]:.4f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)")
            ckpt.save(i + 1, {"lora": params["lora"]})
    print(f"[lora-ft] loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(improved {losses[0] - losses[-1]:.3f})")
    assert losses[-1] < losses[0], "LoRA fine-tuning should reduce loss"


if __name__ == "__main__":
    main()
