"""Quickstart: RELIEF vs FedAvg on a synthetic PAMAP2 fleet in ~2 minutes.

Runs the paper's core comparison end-to-end: 8 heterogeneous clients
(3 full-modality fast, 3 dual-modality mid, 2 single-modality slow), the
lightweight-CNN backbone, 12 federated rounds — and prints F1, simulated
round time, energy and upload volume for both methods.

  PYTHONPATH=src python examples/quickstart.py [--rounds 12]
"""
import argparse

import jax
import numpy as np

from repro.core import strategies
from repro.core.engine import FedConfig, FedRun
from repro.core.tasks import MMTask
from repro.data import make_har_dataset, mm_config_for
from repro.sim import make_fleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print("=> synthesizing PAMAP2-like data (4 modalities, 12 activities)")
    ds = make_har_dataset("pamap2", windows_per_subject=160, seed=args.seed)
    fleet = make_fleet(3, 3, 2, M=4)  # paper's coupled cost gradient
    print(f"   fleet: {fleet.type_names} (TOPS: {fleet.tops.tolist()})")

    cfg = mm_config_for("pamap2", backbone="cnn", d_feat=16, d_fused=64,
                        cnn_ch=(16, 32))
    task, tr0 = MMTask.create(cfg, jax.random.PRNGKey(args.seed))
    print(f"   parameter groups (G={task.layout.G}): {task.layout.names}")

    fed = FedConfig(rounds=args.rounds, eval_every=max(args.rounds // 4, 1),
                    utilization=2e-5, seed=args.seed)
    results = {}
    for name in ("fedavg", "relief"):
        print(f"=> training with {name}")
        run = FedRun.create(task, tr0, strategies.get(name), fleet, fed)
        h = run.run(ds, log_every=max(args.rounds // 4, 1))
        results[name] = h

    fa, rl = results["fedavg"], results["relief"]
    t_fa, t_rl = np.mean(fa["round_time_s"]), np.mean(rl["round_time_s"])
    e_fa, e_rl = np.mean(fa["energy_j"]), np.mean(rl["energy_j"])
    print("\n================ quickstart summary ================")
    print(f"{'':14s}{'FedAvg':>10s}{'RELIEF':>10s}")
    print(f"{'macro-F1':14s}{fa['f1'][-1]:>10.3f}{rl['f1'][-1]:>10.3f}")
    print(f"{'round time':14s}{t_fa:>9.2f}s{t_rl:>9.2f}s"
          f"   (speedup {t_fa / t_rl:.2f}x)")
    print(f"{'fleet energy':14s}{e_fa:>9.0f}J{e_rl:>9.0f}J"
          f"   (saving {100 * (1 - e_rl / e_fa):.0f}%)")
    print(f"{'upload':14s}{np.mean(fa['upload_mb']):>8.2f}MB"
          f"{np.mean(rl['upload_mb']):>8.2f}MB")
    assert t_rl < t_fa, "RELIEF should beat FedAvg on round time"


if __name__ == "__main__":
    main()
