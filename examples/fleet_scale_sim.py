"""Fleet-scale async simulation: N clients, buffered flushes, churn.

Drives the vectorized structure-of-arrays runtime
(core/async_engine.py:VectorizedAsyncFedRun) in pure system-simulation mode
— per-client timing, energy, staleness and population churn for fleets up
to 10^6 devices, no gradient work — and prints the staleness distribution
and wall-clock throughput.

  PYTHONPATH=src python examples/fleet_scale_sim.py --n 100000 \
      --flushes 300 --churn-rate 0.01 --arrival-rate 0.02
"""
import argparse
import time

import jax
import numpy as np

from repro.core.async_engine import AsyncFedConfig, VectorizedAsyncFedRun
from repro.core.tasks import MMTask
from repro.data import get_provider
from repro.sim import FleetConfig, ScenarioSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000, help="fleet size")
    ap.add_argument("--flushes", type=int, default=300,
                    help="server versions to simulate")
    ap.add_argument("--buffer", type=int, default=64, help="FedBuff K")
    ap.add_argument("--churn-rate", type=float, default=0.0,
                    help="departures per alive client per sim-second")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="re-arrivals per departed client per sim-second")
    ap.add_argument("--jitter", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # pure system simulation: the spec drives fleet + runtime config, but
    # no dataset is built (grad_mode="none" skips all gradient work)
    spec = ScenarioSpec(
        "fleet_scale", n_clients=args.n, strategy="async_relief",
        strategy_args=(("buffer_size", args.buffer),), rounds=1,
        local_epochs=1, steps_per_epoch=1, batch_size=4, eval_every=0,
        jitter_sigma=args.jitter, grad_mode="none", seed=args.seed)
    fleet = FleetConfig.from_scenario(spec)
    cfg = get_provider(spec.dataset).mm_config(spec.backbone,
                                               small=spec.small_model)
    task, tr0 = MMTask.create(cfg, jax.random.PRNGKey(args.seed))
    fed = AsyncFedConfig.from_scenario(spec, churn_rate=args.churn_rate,
                                       arrival_rate=args.arrival_rate)
    run = VectorizedAsyncFedRun.create(
        task, tr0, spec.build_strategy(), fleet, fed)

    total = args.flushes * min(args.buffer, args.n)
    t0 = time.perf_counter()
    run.run(None, total_updates=total)
    wall = time.perf_counter() - t0

    h = run.history
    stale = np.asarray(h["staleness_mean"])
    ups = run.fstate.updates
    print(f"\nfleet N={args.n:,d}  buffer K={args.buffer}  "
          f"flushes {run.trace.flushes}  completions "
          f"{run.trace.completions:,d}")
    print(f"wall {wall:.2f}s  ->  "
          f"{run.trace.completions / wall:,.0f} events/s, "
          f"{run.trace.flushes / wall:,.1f} flushes/s")
    print(f"simulated {run.state.sim_time:,.1f}s of fleet time, "
          f"energy {run.trace.energy_j:,.0f} J, "
          f"upload {run.trace.upload_mb:,.1f} MB")
    print(f"staleness/flush: mean {stale.mean():.1f}  "
          f"p50 {np.percentile(stale, 50):.1f}  "
          f"p95 {np.percentile(stale, 95):.1f}  max {stale.max():.1f}")
    print(f"per-client updates: mean {ups.mean():.2f}  max {ups.max()}  "
          f"idle {(ups == 0).mean():.1%}")
    if args.churn_rate > 0 or args.arrival_rate > 0:
        print(f"population: alive {run.fstate.alive.mean():.1%}")


if __name__ == "__main__":
    main()
