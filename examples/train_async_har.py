"""End-to-end driver for the event-driven asynchronous runtime.

Runs RELIEF-style divergence-guided allocation under buffered,
staleness-discounted cohort aggregation on a heterogeneous fleet, and
prints the simulated wall-clock/energy comparison against a synchronous
FedAvg run doing the same total client work.

  PYTHONPATH=src python examples/train_async_har.py \
      [--rounds 50] [--buffer 4] [--staleness-exp 0.5] [--hetero 100]
"""
import argparse

import jax
import numpy as np

from repro.core import strategies
from repro.core.async_engine import AsyncFedRun
from repro.core.engine import FedConfig, FedRun
from repro.core.tasks import MMTask
from repro.data import get_provider
from repro.sim import ScenarioSpec, build_scenario


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=50,
                    help="logical rounds: total work = rounds * N updates")
    ap.add_argument("--dataset", default="pamap2")
    ap.add_argument("--buffer", type=int, default=4,
                    help="server buffer size K (flush threshold)")
    ap.add_argument("--staleness-exp", type=float, default=0.5,
                    help="a in the 1/(1+s)^a staleness discount")
    ap.add_argument("--hetero", type=float, default=100.0,
                    help="Full/Low compute gap (paper Tables IV-V)")
    ap.add_argument("--jitter", type=float, default=0.0,
                    help="lognormal compute-time noise sigma")
    ap.add_argument("--codec", default="none", choices=("none", "int8"),
                    help="uplink codec: int8 quantizes client deltas "
                         "(error feedback on-device, fused server ingest)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # one frozen spec describes the whole experiment; build_scenario
    # materializes dataset + fleet + strategy + AsyncFedConfig from it
    spec = ScenarioSpec(
        "train_async_har", dataset=args.dataset, windows_per_subject=200,
        fleet=(3, 3, 2 if args.dataset == "pamap2" else 4),
        hetero_scale=args.hetero, strategy="async_relief",
        strategy_args=(("buffer_size", args.buffer),
                       ("staleness_exponent", args.staleness_exp)),
        uplink_codec=args.codec, rounds=args.rounds,
        eval_every=max(args.rounds // 2, 1), t_overhead=1e-3,
        jitter_sigma=args.jitter, seed=args.seed)
    sc = build_scenario(spec)
    cfg = get_provider(args.dataset).mm_config(spec.backbone,
                                               small=spec.small_model)
    task, tr0 = MMTask.create(cfg, jax.random.PRNGKey(args.seed))
    print(f"[async driver] {args.dataset}: fleet N={sc.fleet.N} "
          f"({args.hetero:.0f}x compute gap), G={task.layout.G} groups, "
          f"K={args.buffer}, a={args.staleness_exp}")

    # --- synchronous FedAvg reference (same device model, same total work)
    sfed = FedConfig.from_scenario(spec, eval_every=max(args.rounds // 5, 1))
    sync = FedRun.create(task, tr0, strategies.get("fedavg"), sc.fleet, sfed)
    hs = sync.run(sc.dataset)
    sync_total = float(np.sum(hs["round_time_s"]))
    print(f"[sync fedavg ] {args.rounds} rounds in simulated "
          f"{sync_total:9.2f}s  F1 {hs['f1'][-1]:.3f}  "
          f"E {np.sum(hs['energy_j']):.0f}J")

    # --- event-driven run
    arun = AsyncFedRun.create(task, tr0, sc.strategy, sc.fleet, sc.fed)
    ha = arun.run(sc.dataset, log_every=max(args.rounds * sc.fleet.N
                                            // args.buffer // 10, 1))
    async_total = float(arun.state.sim_time)
    print(f"[async relief] {arun.state.round} flushes "
          f"({arun.trace.completions} updates) in simulated "
          f"{async_total:9.2f}s  F1 {ha['f1'][-1]:.3f}  "
          f"E {arun.trace.energy_j:.0f}J")
    print(f"[async driver] wall-clock speedup vs sync FedAvg: "
          f"{sync_total / max(async_total, 1e-12):.1f}x  "
          f"(mean staleness {np.mean(ha['staleness_mean']):.2f}, "
          f"fast/slow update ratio "
          f"{arun.trace.per_client_updates.max()}"
          f"/{max(arun.trace.per_client_updates.min(), 1)})")


if __name__ == "__main__":
    main()
