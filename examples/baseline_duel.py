"""Head-to-head: all 11 federated methods on one fleet (reduced Table I).

  PYTHONPATH=src python examples/baseline_duel.py [--rounds 10]
"""
import argparse

import jax
import numpy as np

from repro.core.engine import FedConfig, FedRun
from repro.core import strategies
from repro.core.strategies import ALL_BASELINES
from repro.core.tasks import MMTask
from repro.data import make_har_dataset, mm_config_for
from repro.sim import make_fleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--dataset", default="pamap2")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    ds = make_har_dataset(args.dataset, windows_per_subject=120,
                          seed=args.seed)
    n_low = 2 if args.dataset == "pamap2" else 4
    fleet = make_fleet(3, 3, n_low, M=4)
    cfg = mm_config_for(args.dataset, backbone="cnn", d_feat=16, d_fused=64,
                        cnn_ch=(16, 32))
    task, tr0 = MMTask.create(cfg, jax.random.PRNGKey(args.seed))
    fed = FedConfig(rounds=args.rounds, eval_every=args.rounds,
                    utilization=2e-5, seed=args.seed)

    rows = []
    for name in list(ALL_BASELINES) + ["relief"]:
        run = FedRun.create(task, tr0, strategies.get(name), fleet, fed)
        h = run.run(ds)
        rows.append((name, h["f1"][-1], float(np.mean(h["round_time_s"])),
                     float(np.mean(h["energy_j"])),
                     float(np.mean(h["upload_mb"]))))
        print(f"  {name:12s} F1 {rows[-1][1]:.3f} t/r {rows[-1][2]:.2f}s")

    base_t = next(r[2] for r in rows if r[0] == "fedavg")
    print(f"\n{'method':14s}{'F1':>7s}{'t/r':>8s}{'speedup':>9s}"
          f"{'J/r':>8s}{'MB/r':>7s}")
    for name, f1, t, e, mb in sorted(rows, key=lambda r: -r[1]):
        print(f"{name:14s}{f1:7.3f}{t:8.2f}{base_t / t:9.2f}x{e:8.0f}"
              f"{mb:7.2f}")


if __name__ == "__main__":
    main()
