"""End-to-end driver: full RELIEF federated training with checkpointing,
fault injection and final per-modality evaluation (the paper's headline
experiment at reduced scale).

Trains the Backbone-2 setting (frozen transformer encoders + LoRA rho=8 +
MDLoRA fusion) on synthetic MHEALTH for a few hundred rounds by default,
checkpointing server state every 20 rounds and surviving a simulated
mid-run preemption (kill/restore).

  PYTHONPATH=src python examples/train_relief_har.py \
      [--rounds 200] [--backbone b2] [--ckpt-dir /tmp/relief_ckpt]
"""
import argparse
import os

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.engine import FedConfig, FedRun
from repro.core import strategies
from repro.core.tasks import MMTask
from repro.data import make_har_dataset, mm_config_for
from repro.sim import make_fleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--dataset", default="mhealth")
    ap.add_argument("--backbone", default="b2", choices=["b1", "b2"])
    ap.add_argument("--strategy", default="relief")
    ap.add_argument("--ckpt-dir", default="/tmp/relief_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--dropout", type=float, default=0.1,
                    help="per-round client failure probability")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    ds = make_har_dataset(args.dataset, windows_per_subject=200,
                          seed=args.seed)
    n_low = 2 if args.dataset == "pamap2" else 4
    fleet = make_fleet(3, 3, n_low, M=4)
    cfg = mm_config_for(
        args.dataset,
        backbone="cnn" if args.backbone == "b1" else "transformer",
        d_feat=16, d_fused=64,
        **({"cnn_ch": (16, 32)} if args.backbone == "b1" else
           {"enc_layers": 2, "enc_d": 32, "enc_ff": 64}))
    task, tr0 = MMTask.create(cfg, jax.random.PRNGKey(args.seed))
    n_train = sum(x.size for x in jax.tree.leaves(tr0))
    n_total = sum(x.size for x in jax.tree.leaves(task.params(tr0)))
    print(f"[driver] {args.dataset}/{args.backbone}: {n_total:,} params, "
          f"{n_train:,} trainable ({100 * n_train / n_total:.2f}%), "
          f"G={task.layout.G} groups, fleet N={fleet.N}, "
          f"client dropout p={args.dropout}")

    fed = FedConfig(rounds=args.rounds, eval_every=10, seed=args.seed,
                    utilization=2e-5, dropout_prob=args.dropout)
    run = FedRun.create(task, tr0, strategies.get(args.strategy), fleet, fed)

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    restored = ckpt.restore_latest({"trainable": run.state.trainable})
    start = 0
    if restored is not None:
        state, meta = restored
        run.state.trainable = state["trainable"]
        run.state.dbar = np.asarray(meta["dbar"])
        start = meta["step"]
        print(f"[driver] resumed from round {start}")

    for r in range(start, args.rounds):
        rec = run.round(ds)
        if (r + 1) % fed.eval_every == 0:
            f1 = run.evaluate(ds)
            run.history["f1"].append(f1)
            run.history["f1_round"].append(rec["round"])
            print(f"[round {r + 1:4d}] loss {rec['loss']:.4f} F1 {f1:.4f} "
                  f"t/r {rec['round_time_s']:.2f}s "
                  f"sel {rec['selected_frac']:.2f}")
        if (r + 1) % args.ckpt_every == 0:
            ckpt.save(r + 1, {"trainable": run.state.trainable},
                      {"dbar": run.state.dbar.tolist(),
                       "strategy": args.strategy})

    xs = np.concatenate(ds.test_x)
    ys = np.concatenate(ds.test_y)
    per_mod = task.eval_per_modality(run.state.trainable, xs, ys)
    print("\n[driver] final per-modality F1 (paper Fig. 6):")
    for k, v in per_mod.items():
        print(f"    {k:6s} {v:.3f}")
    print(f"[driver] overall F1 {run.history['f1'][-1]:.3f}; "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
