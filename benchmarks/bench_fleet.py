"""Fleet-scale throughput benchmark for the vectorized async runtime.

Sweeps the structure-of-arrays simulator (core/async_engine.py
VectorizedAsyncFedRun) over fleet sizes N = 10^2 .. 10^6 in pure
system-simulation mode (grad_mode="none": timing / energy / staleness for
the full fleet, no gradient work), plus decoupled-gradient cells at 10^4
(grad_mode="cohort": local updates only for the K clients of each flush)
and a churn cell exercising the population model. Each cell runs a fixed
number of server flushes and reports wall-clock throughput:

    events_per_s   absorbed client completions per wall second
    flushes_per_s  server versions per wall second

Outputs
    benchmarks/results/bench_fleet.json   full sweep (schema-stable)
    BENCH_fleet.json (repo root)          committed baseline, written by
                                          --update-baseline; --smoke runs
                                          the N=10^4 cell only and exits
                                          nonzero if throughput regressed
                                          more than 2x against it (the CI
                                          perf gate).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, SCHEMA_VERSION, write_json

BASELINE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                             "BENCH_fleet.json")
FLUSHES = 200  # server versions per cell
BUFFER_K = 64
SMOKE_N = 10_000
REGRESSION_FACTOR = 2.0


def _build(seed: int = 0):
    import jax

    from repro.core.tasks import MMTask
    from repro.data import mm_config_for

    cfg = mm_config_for("pamap2", backbone="cnn", d_feat=16, d_fused=64,
                        cnn_ch=(16, 32))
    return MMTask.create(cfg, jax.random.PRNGKey(seed))


def _cell(task, tr0, n: int, grad_mode: str, dataset=None,
          churn_rate: float = 0.0, arrival_rate: float = 0.0,
          flushes: int = FLUSHES, seed: int = 0) -> dict:
    from repro.core.async_engine import AsyncFedConfig, VectorizedAsyncFedRun
    from repro.core.strategies import async_relief
    from repro.sim import make_fleet, scale_fleet

    fleet = scale_fleet(make_fleet(3, 3, 2, M=4), n,
                        np.random.default_rng(seed))
    fed = AsyncFedConfig(rounds=1, local_epochs=1, steps_per_epoch=1,
                         batch_size=4, eval_every=0, seed=seed,
                         utilization=2e-5, t_overhead=0.05,
                         jitter_sigma=0.1, grad_mode=grad_mode,
                         churn_rate=churn_rate, arrival_rate=arrival_rate)
    run = VectorizedAsyncFedRun.create(
        task, tr0, async_relief(buffer_size=BUFFER_K), fleet, fed)
    total = flushes * min(BUFFER_K, n)
    t0 = time.perf_counter()
    run.run(dataset, total_updates=total)
    wall = time.perf_counter() - t0
    completions = run.trace.completions
    h = run.history
    return {
        "n": n, "grad_mode": grad_mode, "churn_rate": churn_rate,
        "flushes": run.trace.flushes, "completions": completions,
        "wall_s": round(wall, 4),
        "events_per_s": round(completions / max(wall, 1e-9), 2),
        "flushes_per_s": round(run.trace.flushes / max(wall, 1e-9), 2),
        "sim_time_s": round(run.state.sim_time, 4),
        "staleness_mean": round(float(np.mean(h["staleness_mean"])), 3),
        "staleness_p95": round(
            float(np.percentile(h["staleness_mean"], 95)), 3),
        "energy_j": round(run.trace.energy_j, 2),
        "alive_frac": round(float(run.fstate.alive.mean()), 4),
    }


def run_sweep(smoke: bool = False, max_n: int = 1_000_000,
              seed: int = 0) -> list[dict]:
    task, tr0 = _build(seed)
    rows = []
    if smoke:
        rows.append(_cell(task, tr0, SMOKE_N, "none", seed=seed))
        return rows
    for n in (100, 10_000, 100_000, 1_000_000):
        if n > max_n:
            continue
        rows.append(_cell(task, tr0, n, "none", seed=seed))
        print(f"  N={n:>9,d} none    {rows[-1]['events_per_s']:>12,.0f} ev/s "
              f"wall {rows[-1]['wall_s']:7.2f}s "
              f"stale {rows[-1]['staleness_mean']:.2f}")
    from repro.data import make_har_dataset
    ds = make_har_dataset("pamap2", windows_per_subject=60, seed=seed)
    rows.append(_cell(task, tr0, 10_000, "cohort", dataset=ds, flushes=20,
                      seed=seed))
    print(f"  N={10_000:>9,d} cohort  {rows[-1]['events_per_s']:>12,.0f} ev/s "
          f"wall {rows[-1]['wall_s']:7.2f}s (gradients for "
          f"{rows[-1]['completions']} of 10,000 clients)")
    rows.append(_cell(task, tr0, 10_000, "none", churn_rate=0.02,
                      arrival_rate=0.02, seed=seed))
    print(f"  N={10_000:>9,d} churn   {rows[-1]['events_per_s']:>12,.0f} ev/s "
          f"alive {rows[-1]['alive_frac']:.2%}")
    return rows


def check_regression(rows: list[dict]) -> int:
    """CI gate: N=10^4 smoke throughput must stay within REGRESSION_FACTOR
    of the committed baseline."""
    if not os.path.exists(BASELINE_PATH):
        print("no committed BENCH_fleet.json baseline; skipping gate")
        return 0
    with open(BASELINE_PATH) as f:
        base = json.load(f)
    base_row = next((r for r in base.get("rows", [])
                     if r["n"] == SMOKE_N and r["grad_mode"] == "none"
                     and r.get("churn_rate", 0.0) == 0.0), None)
    cur_row = next((r for r in rows
                    if r["n"] == SMOKE_N and r["grad_mode"] == "none"
                    and r.get("churn_rate", 0.0) == 0.0), None)
    if base_row is None or cur_row is None:
        print("baseline or current N=1e4 row missing; skipping gate")
        return 0
    floor = base_row["events_per_s"] / REGRESSION_FACTOR
    status = "OK" if cur_row["events_per_s"] >= floor else "REGRESSION"
    print(f"perf gate: {cur_row['events_per_s']:,.0f} ev/s vs baseline "
          f"{base_row['events_per_s']:,.0f} ev/s (floor {floor:,.0f}) "
          f"-> {status}")
    return 0 if status == "OK" else 1


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="N=1e4 cell only + regression gate (CI)")
    ap.add_argument("--max-n", type=int, default=1_000_000,
                    help="largest fleet size in the sweep")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the committed BENCH_fleet.json baseline")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rows = run_sweep(smoke=args.smoke, max_n=args.max_n, seed=args.seed)
    payload = {"schema_version": SCHEMA_VERSION, "buffer_size": BUFFER_K,
               "flushes_per_cell": FLUSHES, "rows": rows}
    write_json(os.path.join(RESULTS_DIR, "bench_fleet.json"), payload)
    if args.update_baseline:
        write_json(os.path.abspath(BASELINE_PATH), payload)
        print(f"baseline written: {os.path.abspath(BASELINE_PATH)}")
    return check_regression(rows)


if __name__ == "__main__":
    raise SystemExit(main())
