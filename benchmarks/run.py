"""Benchmark suite entry point — one bench per paper table/figure.

  python -m benchmarks.run [--quick | --full] [--only main_b1,ablation,...]

Default ("standard") runs reduced-but-faithful configurations suitable for
the 1-core CPU container (DESIGN.md §7): identical fleet topology, compute
gap and protocol as the paper, smaller models/rounds. ``--quick`` is the CI
smoke (few rounds, subset of methods); ``--full`` is paper-scale. Underlying
federated runs are cached under benchmarks/results/runs/, so the suite is
resumable and benches share runs.

Every bench runs inside a failure boundary: the suite always writes
benchmarks/results/summary.json (schema-stable; uploaded as the CI
artifact) and exits nonzero if ANY bench failed — the smoke job gates on
this exit code.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the per-mode default round count")
    ap.add_argument("--only", default=None,
                    help="comma list: motivation,main_b1,main_b2,ablation,"
                         "sensitivity,convergence,permodality,device,"
                         "async,roofline")
    args = ap.parse_args()
    # "standard" defaults are calibrated to this 1-core CPU container
    # (protocol/fleet identical to the paper; --full restores paper scale)
    rounds = args.rounds or (6 if args.quick else (200 if args.full else 8))
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    from benchmarks import (bench_ablation, bench_async, bench_convergence,
                            bench_device_profile, bench_main,
                            bench_motivation, bench_permodality,
                            bench_roofline, bench_sensitivity)
    from benchmarks.common import RESULTS_DIR, write_json

    mode = "quick" if args.quick else "full" if args.full else "standard"
    benches = [
        ("motivation", lambda: bench_motivation.run(rounds=min(rounds, 24),
                                                    quick=args.quick)),
        ("main_b1", lambda: bench_main.run("b1", rounds=rounds,
                                           quick=args.quick)),
        ("main_b2", lambda: bench_main.run("b2",
                                           rounds=max(rounds * 2 // 3, 4),
                                           quick=args.quick)),
        ("ablation", lambda: bench_ablation.run(rounds=rounds,
                                                quick=args.quick)),
        ("sensitivity", lambda: bench_sensitivity.run(
            rounds=max(rounds * 2 // 3, 4), quick=args.quick)),
        ("convergence", lambda: bench_convergence.run(rounds=rounds,
                                                      quick=args.quick)),
        ("permodality", lambda: bench_permodality.run(rounds=rounds,
                                                      quick=args.quick)),
        ("device", lambda: bench_device_profile.run(
            rounds=max(rounds * 2 // 3, 4), quick=args.quick)),
        ("async", lambda: bench_async.run(rounds=rounds, quick=args.quick)),
    ]

    t0 = time.time()
    print(f"[benchmarks.run] mode={mode}")
    results = []
    for name, fn in benches:
        if not want(name):
            continue
        t1 = time.time()
        entry = {"bench": name, "status": "ok"}
        try:
            fn()
        except Exception as e:  # noqa: BLE001 — boundary: record + gate
            entry["status"] = "error"
            entry["error"] = repr(e)
            traceback.print_exc()
        entry["duration_s"] = round(time.time() - t1, 1)
        results.append(entry)
        print(f"[benchmarks.run] {name}: {entry['status']} "
              f"({entry['duration_s']}s)")
    if want("roofline"):
        entry = {"bench": "roofline", "status": "ok"}
        try:
            bench_roofline.run("single")
            bench_roofline.run("multi")
        except FileNotFoundError as e:  # dry-run results may not exist yet
            entry["status"] = "skipped"
            entry["reason"] = str(e)
            print(f"[roofline] skipped: {e}")
        except Exception as e:  # noqa: BLE001
            entry["status"] = "error"
            entry["error"] = repr(e)
            traceback.print_exc()
        results.append(entry)

    failed = [r["bench"] for r in results if r["status"] == "error"]
    summary = {"mode": mode, "rounds": rounds,
               "duration_s": round(time.time() - t0, 1),
               "benches": results, "failed": failed,
               "ok": not failed}
    write_json(os.path.join(RESULTS_DIR, "summary.json"), summary)
    print(f"[benchmarks.run] done in {summary['duration_s']}s; "
          f"{'ALL OK' if not failed else 'FAILED: ' + ','.join(failed)}")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
