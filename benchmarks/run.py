"""Benchmark suite entry point — one bench per paper table/figure.

  python -m benchmarks.run [--quick | --full] [--only main_b1,ablation,...]

Default ("standard") runs reduced-but-faithful configurations suitable for
the 1-core CPU container (DESIGN.md §7): identical fleet topology, compute
gap and protocol as the paper, smaller models/rounds. ``--quick`` is the CI
smoke (few rounds, subset of methods); ``--full`` is paper-scale. Underlying
federated runs are cached under benchmarks/results/runs/, so the suite is
resumable and benches share runs.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the per-mode default round count")
    ap.add_argument("--only", default=None,
                    help="comma list: motivation,main_b1,main_b2,ablation,"
                         "sensitivity,convergence,permodality,device,"
                         "roofline")
    args = ap.parse_args()
    # "standard" defaults are calibrated to this 1-core CPU container
    # (protocol/fleet identical to the paper; --full restores paper scale)
    rounds = args.rounds or (6 if args.quick else (200 if args.full else 8))
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    from benchmarks import (bench_ablation, bench_convergence,
                            bench_device_profile, bench_main,
                            bench_motivation, bench_permodality,
                            bench_roofline, bench_sensitivity)

    t0 = time.time()
    print(f"[benchmarks.run] mode="
          f"{'quick' if args.quick else 'full' if args.full else 'standard'}")
    if want("motivation"):
        bench_motivation.run(rounds=min(rounds, 24), quick=args.quick)
    if want("main_b1"):
        bench_main.run("b1", rounds=rounds, quick=args.quick)
    if want("main_b2"):
        bench_main.run("b2", rounds=max(rounds * 2 // 3, 4),
                       quick=args.quick)
    if want("ablation"):
        bench_ablation.run(rounds=rounds, quick=args.quick)
    if want("sensitivity"):
        bench_sensitivity.run(rounds=max(rounds * 2 // 3, 4),
                              quick=args.quick)
    if want("convergence"):
        bench_convergence.run(rounds=rounds, quick=args.quick)
    if want("permodality"):
        bench_permodality.run(rounds=rounds, quick=args.quick)
    if want("device"):
        bench_device_profile.run(rounds=max(rounds * 2 // 3, 4),
                                 quick=args.quick)
    if want("roofline"):
        try:
            bench_roofline.run("single")
            bench_roofline.run("multi")
        except Exception as e:  # dry-run results may not exist yet
            print(f"[roofline] skipped: {e}")
    print(f"[benchmarks.run] done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
