"""Tables I & II: main results across 11 methods x 2 datasets per backbone.

Columns mirror the paper: F1 per dataset, rare-modality F1, speedup vs
FedAvg (straggler-bound round time), TTA, comm volume, energy.
"""
from __future__ import annotations

import os

from benchmarks.common import (RESULTS_DIR, BenchSpec, METHOD_LABELS,
                               fmt_table, run_spec, save_csv, tta_rounds)

METHODS_B1 = ["fedavg", "fedprox", "fedel", "fedicu", "darkdistill",
              "harmony", "pilot", "fedsa_lora", "helora", "fedlease",
              "relief"]
# B2 standard profile: the 6 methods the paper's B2 analysis centres on;
# --full runs all 11 (container compile budget — DESIGN.md §7)
METHODS_B2 = ["fedavg", "fedel", "harmony", "fedsa_lora", "helora",
              "relief"]


def run(backbone: str = "b1", rounds: int = 30, seed: int = 0,
        methods=None, quick: bool = False) -> list[dict]:
    methods = methods or (METHODS_B1 if backbone == "b1" else METHODS_B2)
    if quick:
        methods = ["fedavg", "fedel", "harmony", "relief"]
        rounds = min(rounds, 6)
    rows = []
    for ds in ("pamap2", "mhealth"):
        print(f"[bench_main:{backbone}] dataset={ds}")
        base = run_spec(BenchSpec("fedavg", ds, backbone, rounds, seed))
        thresh = 0.95 * base["f1"]
        for m in methods:
            r = run_spec(BenchSpec(m, ds, backbone, rounds, seed))
            tta = tta_rounds(r["f1_curve"], r["f1_rounds"], thresh)
            rows.append({
                "method": METHOD_LABELS.get(m, m), "dataset": ds,
                "backbone": backbone, "f1": r["f1"],
                "rare_mod_f1": r["rare_mod_f1"],
                "speedup": base["round_time_s"] / max(r["round_time_s"],
                                                      1e-9),
                "tta_rounds": tta if tta is not None else "-",
                "comm_mb": r["upload_mb"],
                "energy_j": r["energy_j"],
                "energy_save_pct": 100 * (1 - r["energy_j"]
                                          / max(base["energy_j"], 1e-9)),
            })
    cols = [("method", "method"), ("dataset", "dataset"), ("F1", "f1"),
            ("RareF1", "rare_mod_f1"), ("Speedup", "speedup"),
            ("TTA", "tta_rounds"), ("MB/r", "comm_mb"),
            ("J/r", "energy_j"), ("Esave%", "energy_save_pct")]
    print(fmt_table(rows, cols,
                    f"Table {'I' if backbone == 'b1' else 'II'} "
                    f"(Backbone {backbone})"))
    save_csv(rows, os.path.join(RESULTS_DIR, f"table_main_{backbone}.csv"),
             [k for _, k in cols])
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--backbone", default="b1", choices=["b1", "b2"])
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(a.backbone, a.rounds, quick=a.quick)
