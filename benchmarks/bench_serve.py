"""Personalized-serving benchmark: continuous batching + gathered multi-LoRA
decode vs naive one-model-at-a-time serving.

RELIEF gives every client its own modality-block adapter, so "serving the
fleet" means serving many tiny model variants at once. The naive baseline
merges each request's adapter into a single-adapter model and decodes
requests sequentially (jitted, but batch 1 and one dispatch chain per
request). The engine (launch/serving_engine.py) decodes a mixed batch in
lockstep: per-row ``adapter_idx`` gathers each request's adapter inside the
fused mdlora kernel and requests join/leave the batch at step granularity.

Sweeps batch-slots x n_adapters x request-length distribution on the
phi3-medium SMOKE arch (CPU interpret-class numbers — relative speedups are
the signal, not absolute tok/s). Every cell first checks the engine's
greedy tokens are *identical* to the naive baseline's, then times both.

Outputs
    benchmarks/results/bench_serve.json   full sweep (schema-stable)
    BENCH_serve.json (repo root)          committed baseline, written by
                                          --update-baseline; --smoke runs
                                          the batch=16 x 16-adapter cell
                                          only and exits nonzero if the
                                          engine speedup falls below
                                          MIN_SPEEDUP or throughput
                                          regresses >2x vs the baseline.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import RESULTS_DIR, SCHEMA_VERSION, write_json

BASELINE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                             "BENCH_serve.json")
ARCH = "phi3-medium-14b"
NEW_TOKENS = 8
PROMPT_LENS = {"uniform": (6, 6), "ragged": (4, 10)}
CELLS = ((4, 4, "uniform"), (8, 8, "ragged"), (16, 16, "uniform"),
         (16, 16, "ragged"), (16, 4, "ragged"))
SMOKE_CELL = (16, 16, "uniform")
MIN_SPEEDUP = 3.0
REGRESSION_FACTOR = 2.0


def _requests(cfg, n, n_adapters, dist, seed):
    from repro.launch.serving_engine import Request

    rng = np.random.default_rng(seed)
    lo, hi = PROMPT_LENS[dist]
    return [Request(rid=f"r{i}",
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(lo, hi + 1))),
                    adapter=f"c{i % n_adapters}",
                    max_new_tokens=NEW_TOKENS) for i in range(n)]


def _setup(n_adapters, seed):
    import jax

    from repro.configs import base
    from repro.launch.serving_engine import AdapterRegistry
    from repro.models import api

    cfg = base.get_arch(ARCH).SMOKE
    params = api.init_model(jax.random.PRNGKey(seed), cfg)
    reg = AdapterRegistry(jax.random.PRNGKey(1), cfg, capacity=n_adapters)
    rng = np.random.default_rng(seed)
    nb = len(reg.block_dims)
    for i in range(n_adapters):
        lora = api.init_model(jax.random.PRNGKey(50 + i), cfg)["lora"]
        lora = jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(99 + i), x.shape, x.dtype), lora)
        mm = np.ones(nb, np.float32)
        if nb > 1 and i % 2:
            mm[int(rng.integers(1, nb))] = 0.0  # some clients miss a block
        reg.register(f"c{i}", lora, modality_mask=mm)
    return cfg, params, reg


def _run_engine(params, cfg, reg, reqs, batch_slots, max_len):
    from repro.launch.serving_engine import ServingEngine

    eng = ServingEngine(params, cfg, reg, batch_slots=batch_slots,
                        max_len=max_len)
    for r in reqs:
        eng.submit(r)
    return eng.run()


def _cell(batch_slots, n_adapters, dist, seed=0) -> dict:
    from repro.launch.serving_engine import naive_serve

    cfg, params, reg = _setup(n_adapters, seed)
    n_requests = 2 * batch_slots  # oversubscribed: slots recycle mid-run
    reqs = _requests(cfg, n_requests, n_adapters, dist, seed)
    max_len = PROMPT_LENS[dist][1] + NEW_TOKENS + 2

    # correctness first: batched gathered decode == per-request merged decode
    warm_naive = naive_serve(params, cfg, reg, reqs, max_len)
    warm_eng = _run_engine(params, cfg, reg, reqs, batch_slots, max_len)
    assert warm_eng["outputs"] == warm_naive["outputs"], \
        "engine tokens diverged from per-request baseline"

    # timed second pass (jit caches warm for both paths)
    eng = _run_engine(params, cfg, reg, reqs, batch_slots, max_len)
    naive = naive_serve(params, cfg, reg, reqs, max_len)
    step_ms = 1e3 * np.asarray(eng["decode_step_times"] or [0.0])
    return {
        "arch": ARCH, "batch_slots": batch_slots, "n_adapters": n_adapters,
        "dist": dist, "n_requests": n_requests, "new_tokens": NEW_TOKENS,
        "generated_tokens": eng["generated_tokens"],
        "engine_tok_s": round(eng["tok_s"], 2),
        "naive_tok_s": round(naive["tok_s"], 2),
        "speedup": round(eng["tok_s"] / max(naive["tok_s"], 1e-9), 3),
        "engine_wall_s": round(eng["wall_s"], 4),
        "naive_wall_s": round(naive["wall_s"], 4),
        "latency_p50_s": round(eng["latency_p50_s"], 4),
        "latency_p99_s": round(eng["latency_p99_s"], 4),
        "decode_step_p50_ms": round(float(np.percentile(step_ms, 50)), 3),
        "decode_step_p99_ms": round(float(np.percentile(step_ms, 99)), 3),
    }


def _roofline(batch_slots, n_adapters) -> list[dict]:
    """Autotuned block plan for the cell's gathered projections."""
    from repro.configs import base
    from repro.launch.roofline import mdlora_block_plan

    cfg = base.get_arch(ARCH).SMOKE
    hhd = cfg.n_heads * cfg.head_dim
    shapes = [
        {"T": batch_slots, "D": cfg.d_model, "F": hhd, "r": cfg.lora_rank,
         "multi": True, "n_adapters": n_adapters},  # wq
        {"T": batch_slots, "D": hhd, "F": cfg.d_model, "r": cfg.lora_rank,
         "multi": True, "n_adapters": n_adapters},  # wo (fusion)
    ]
    return mdlora_block_plan(shapes)


def run_sweep(smoke: bool = False, seed: int = 0) -> list[dict]:
    rows = []
    cells = (SMOKE_CELL,) if smoke else CELLS
    for bs, na, dist in cells:
        rows.append(_cell(bs, na, dist, seed=seed))
        r = rows[-1]
        print(f"  B={bs:>2d} A={na:>2d} {dist:7s} engine "
              f"{r['engine_tok_s']:8.1f} tok/s  naive "
              f"{r['naive_tok_s']:8.1f} tok/s  speedup "
              f"{r['speedup']:5.2f}x  p50 {r['latency_p50_s']:.3f}s "
              f"p99 {r['latency_p99_s']:.3f}s")
    return rows


def check_gate(rows: list[dict]) -> int:
    """CI gate on the batch=16 x 16-adapter cell: the gathered batched path
    must hold >= MIN_SPEEDUP over naive serving, and must not have
    regressed more than REGRESSION_FACTOR vs the committed baseline."""
    bs, na, dist = SMOKE_CELL
    cur = next((r for r in rows if r["batch_slots"] == bs
                and r["n_adapters"] == na and r["dist"] == dist), None)
    if cur is None:
        print("smoke cell missing; skipping gate")
        return 0
    if cur["speedup"] < MIN_SPEEDUP:
        print(f"perf gate: speedup {cur['speedup']:.2f}x < "
              f"{MIN_SPEEDUP:.1f}x floor -> REGRESSION")
        return 1
    if os.path.exists(BASELINE_PATH):
        with open(BASELINE_PATH) as f:
            base = json.load(f)
        brow = next((r for r in base.get("rows", [])
                     if r["batch_slots"] == bs and r["n_adapters"] == na
                     and r["dist"] == dist), None)
        if brow is not None:
            floor = brow["engine_tok_s"] / REGRESSION_FACTOR
            status = "OK" if cur["engine_tok_s"] >= floor else "REGRESSION"
            print(f"perf gate: engine {cur['engine_tok_s']:.1f} tok/s vs "
                  f"baseline {brow['engine_tok_s']:.1f} "
                  f"(floor {floor:.1f}) -> {status}; speedup "
                  f"{cur['speedup']:.2f}x (>= {MIN_SPEEDUP:.1f}x) -> OK")
            return 0 if status == "OK" else 1
    print(f"perf gate: speedup {cur['speedup']:.2f}x >= "
          f"{MIN_SPEEDUP:.1f}x -> OK (no committed baseline to compare)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="batch=16 x 16-adapter cell only + CI gate")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the committed BENCH_serve.json baseline")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rows = run_sweep(smoke=args.smoke, seed=args.seed)
    payload = {"schema_version": SCHEMA_VERSION, "arch": ARCH,
               "new_tokens": NEW_TOKENS, "rows": rows,
               "roofline": _roofline(*SMOKE_CELL[:2])}
    write_json(os.path.join(RESULTS_DIR, "bench_serve.json"), payload)
    if args.update_baseline:
        write_json(os.path.abspath(BASELINE_PATH), payload)
        print(f"baseline written: {os.path.abspath(BASELINE_PATH)}")
    return check_gate(rows)


if __name__ == "__main__":
    raise SystemExit(main())
