"""Fig. 6: per-modality F1 breakdown — RELIEF's gains concentrate on the
rare modalities (Mag, HR/ECG), consistent with Theorem 3's cohort residual."""
from __future__ import annotations

import os

from benchmarks.common import (RESULTS_DIR, BenchSpec, fmt_table, run_spec,
                               save_csv)

METHODS = ["fedavg", "harmony", "relief"]


def run(rounds: int = 30, seed: int = 0, quick: bool = False) -> list[dict]:
    methods = METHODS if not quick else ["fedavg", "relief"]
    if quick:
        rounds = 6
    rows = []
    for backbone in ("b1",):
        for ds in ("pamap2", "mhealth"):
            for m in methods:
                r = run_spec(BenchSpec(m, ds, backbone, rounds, seed))
                row = {"backbone": backbone, "dataset": ds, "method": m}
                row.update({f"f1_{k}": v
                            for k, v in r["per_modality_f1"].items()})
                rows.append(row)
    mods = sorted({k for row in rows for k in row if k.startswith("f1_")})
    cols = ([("backbone", "backbone"), ("dataset", "dataset"),
             ("method", "method")] + [(m[3:], m) for m in mods])
    print(fmt_table(rows, cols, "Fig. 6 (per-modality F1)"))
    save_csv(rows, os.path.join(RESULTS_DIR, "fig_permodality.csv"),
             [k for _, k in cols])
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(a.rounds, quick=a.quick)
