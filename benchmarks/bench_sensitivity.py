"""Tables IV-V: sensitivity to the compute-heterogeneity gap (10x/55x/100x)
and the fleet size (8/10 -> 20 -> 50 -> 100 clients)."""
from __future__ import annotations

import os

from benchmarks.common import (RESULTS_DIR, BenchSpec, fmt_table, run_spec,
                               save_csv)

METHODS = ["fedavg", "fedel", "relief"]


def run(rounds: int = 20, seed: int = 0, dataset: str = "pamap2",
        backbone: str = "b1", quick: bool = False) -> list[dict]:
    methods = METHODS if not quick else ["fedavg", "relief"]
    if quick:
        rounds = 5
    rows = []
    for hetero in (10.0, None, 100.0):  # None = profile default (55x)
        label = {10.0: "mild_10x", None: "moderate_55x",
                 100.0: "extreme_100x"}[hetero]
        row = {"factor": "hetero", "setting": label}
        for m in methods:
            r = run_spec(BenchSpec(m, dataset, backbone, rounds, seed,
                                   hetero_scale=hetero))
            row[m] = r["f1"]
        rows.append(row)
    fleet_sizes = (8, 20, 50, 100) if rounds >= 100 else (8,)
    # N>=20 sweeps only at --full scale (each N recompiles the vmapped
    # client axis; container budget — DESIGN.md §7)
    for n in fleet_sizes:
        row = {"factor": "scale", "setting": f"N={n}"}
        for m in methods:
            r = run_spec(BenchSpec(m, dataset, backbone, rounds, seed,
                                   n_clients=n,
                                   windows=max(40, 160 * 8 // n)))
            row[m] = r["f1"]
        rows.append(row)
    cols = [("factor", "factor"), ("setting", "setting")] + \
        [(m, m) for m in methods]
    print(fmt_table(rows, cols, f"Tables IV-V (sensitivity, {dataset}, "
                                f"{backbone})"))
    save_csv(rows, os.path.join(RESULTS_DIR,
                                f"table_sensitivity_{dataset}_{backbone}.csv"),
             [k for _, k in cols])
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--dataset", default="pamap2")
    ap.add_argument("--backbone", default="b1")
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(a.rounds, dataset=a.dataset, backbone=a.backbone, quick=a.quick)
