"""Async runtime sweep: buffer size x staleness exponent x heterogeneity,
against the synchronous engine under the identical device model.

For each heterogeneity scale the sync FedAvg / sync RELIEF baselines come
from the shared run cache (benchmarks/common.py); each async cell runs the
event-driven engine for the same total client work (rounds * N updates) and
reports

  * total simulated wall-clock for that work (straggler decoupling),
  * wall-clock speedup vs sync FedAvg,
  * time-to-target-loss speedup (target = sync FedAvg's final loss),
  * final F1, fleet energy, upload volume, mean staleness.

Output: benchmarks/results/async_sweep.{json,csv} (schema-stable; the CI
smoke artifact includes the JSON).
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from benchmarks.common import (RESULTS_DIR, SCHEMA_VERSION, BenchSpec,
                               fmt_table, run_spec, save_csv, write_json)


def _async_cell(spec: BenchSpec, buffer_size: int, staleness_exp: float,
                rounds: int) -> dict:
    import jax

    from repro.core.async_engine import AsyncFedRun
    from repro.core.tasks import MMTask
    from repro.data import get_provider
    from repro.sim import ScenarioSpec, build_scenario

    sspec = ScenarioSpec(
        "bench_async", dataset=spec.dataset,
        windows_per_subject=spec.windows,
        fleet=(3, 3, 2 if spec.dataset == "pamap2" else 4),
        hetero_scale=spec.hetero_scale, strategy="async_relief",
        strategy_args=(("buffer_size", buffer_size),
                       ("staleness_exponent", staleness_exp)),
        rounds=rounds, eval_every=0, t_overhead=1e-3, seed=spec.seed)
    sc = build_scenario(sspec, sim_mode=spec.sim_mode)
    cfg = get_provider(spec.dataset).mm_config(sspec.backbone,
                                               small=sspec.small_model)
    task, tr0 = MMTask.create(cfg, jax.random.PRNGKey(spec.seed))
    run = AsyncFedRun.create(task, tr0, sc.strategy, sc.fleet, sc.fed)
    h = run.run(sc.dataset)
    return {"history": h, "run": run, "fleet": sc.fleet}


def _time_to_loss(times, losses, target: float, window: int = 3):
    if len(losses) < window:
        return None
    sm = np.convolve(losses, np.ones(window) / window, mode="valid")
    hit = np.where(sm <= target)[0]
    if hit.size == 0:
        return None
    return float(times[int(hit[0]) + window - 1])


def run(rounds: int = 8, quick: bool = False, seed: int = 0) -> list[dict]:
    hetero_scales = (100.0,) if quick else (10.0, 100.0)
    buffers = (2, 8) if quick else (1, 2, 4, 8)
    exponents = (0.5,) if quick else (0.0, 0.5, 1.0)
    rounds = min(rounds, 4) if quick else rounds

    rows = []
    for hs in hetero_scales:
        spec = BenchSpec("fedavg", "pamap2", "b1", rounds, seed,
                         hetero_scale=hs)
        base = run_spec(spec)
        sync_total = float(np.sum(base["round_times"]))
        sync_target = float(np.mean(base["loss_curve"][-2:]))
        relief_row = run_spec(dataclasses.replace(spec, method="relief"))
        relief_total = float(np.sum(relief_row["round_times"]))
        print(f"[bench_async] hetero={hs:.0f}x sync fedavg "
              f"T={sync_total:.3f}s relief T={relief_total:.3f}s "
              f"target loss {sync_target:.3f}")
        for K in buffers:
            for a in exponents:
                cell = _async_cell(spec, K, a, rounds)
                h = cell["history"]
                t_total = float(cell["run"].state.sim_time)
                tta = _time_to_loss(h["sim_time_s"], h["loss"], sync_target)
                rows.append({
                    "hetero_scale": hs, "buffer_size": K,
                    "staleness_exponent": a, "rounds": rounds,
                    "sim_time_s": t_total,
                    "speedup_vs_sync_fedavg": sync_total / max(t_total, 1e-12),
                    "speedup_vs_sync_relief": relief_total / max(t_total,
                                                                 1e-12),
                    "tta_loss_s": tta if tta is not None else "-",
                    "tta_speedup": (sync_total / tta) if tta else "-",
                    "f1": h["f1"][-1],
                    "energy_j": h["energy_j"][-1],
                    "upload_mb": h["upload_mb"][-1],
                    "staleness_mean": float(np.mean(h["staleness_mean"])),
                    "flushes": int(cell["run"].state.round),
                })
                print(f"  K={K} a={a}: T={t_total:.3f}s "
                      f"({rows[-1]['speedup_vs_sync_fedavg']:.1f}x fedavg) "
                      f"F1 {rows[-1]['f1']:.3f} "
                      f"stale {rows[-1]['staleness_mean']:.2f}")

    cols = [("hetero", "hetero_scale"), ("K", "buffer_size"),
            ("a", "staleness_exponent"), ("T_sim", "sim_time_s"),
            ("xFedAvg", "speedup_vs_sync_fedavg"),
            ("xRELIEF", "speedup_vs_sync_relief"), ("TTA_x", "tta_speedup"),
            ("F1", "f1"), ("stale", "staleness_mean")]
    print(fmt_table(rows, cols, "Async sweep (event-driven runtime)"))
    fields = [k for _, k in cols] + ["tta_loss_s", "energy_j", "upload_mb",
                                     "flushes", "rounds"]
    save_csv(rows, os.path.join(RESULTS_DIR, "async_sweep.csv"), fields)
    write_json(os.path.join(RESULTS_DIR, "async_sweep.json"),
               {"schema_version": SCHEMA_VERSION, "bench": "async_sweep",
                "rows": rows})
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(a.rounds, quick=a.quick)
