"""Server-side ingest benchmark: fused quantized aggregation vs unfused.

Compares the two ways the server can turn a buffered cohort of int8 client
uploads into the Eq. 3 aggregate + Eq. 5 divergence statistics:

    unfused  dequantize the [N, D, r] int8 stack to fp32, apply the FedBuff
             staleness discount to the weights, then run the plain
             cohort_agg_divergence reduction — three jit'd stages with the
             fp32 client stack materialized in between (4 bytes/param of
             HBM/cache traffic before the reduction even starts).
    fused    cohort_agg_divergence_quant: one pass straight off the int8
             payload, dequantizing tiles and applying the per-client
             staleness discount inside the same accumulation — the fp32
             stack never exists.

Sweeps cohort size N in {64, 1024, 16384} at a fixed chunk shape
(D=1024, r=4) and reports median wall time per ingest plus the fused
speedup. A pallas(interpret) cell runs at N=64 for numerical cross-checking
only — interpret mode is not a performance configuration.

Outputs
    benchmarks/results/bench_server_agg.json   full sweep (schema-stable)
    BENCH_server.json (repo root)              committed baseline, written
                                               by --update-baseline; --smoke
                                               runs the N=1024 cell only and
                                               exits nonzero if the fused
                                               ingest got more than 2x
                                               slower than it (CI perf gate).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, SCHEMA_VERSION, write_json

BASELINE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                             "BENCH_server.json")
D, R = 1024, 4  # per-client chunk shape (rows x LoRA rank)
NS = (64, 1024, 16384)
SMOKE_N = 1024
EXPONENT = 0.5  # FedBuff staleness discount 1/(1+s)^a
REPS = 5
REGRESSION_FACTOR = 2.0


def _payload(n: int, seed: int = 0):
    """One buffered cohort: int8 uploads + scales, weights, cohort mask."""
    rng = np.random.default_rng(seed)
    import jax.numpy as jnp
    q = jnp.asarray(rng.integers(-127, 128, (n, D, R), dtype=np.int8))
    scales = jnp.asarray(rng.uniform(1e-4, 1e-2, n).astype(np.float32))
    W = jnp.asarray(rng.uniform(0.0, 1.0, (n, D)).astype(np.float32))
    C = jnp.asarray((rng.uniform(size=(n, D)) < 0.7).astype(np.float32))
    staleness = jnp.asarray(
        rng.integers(0, 8, n).astype(np.float32))
    return q, scales, W, C, staleness


def _timeit(fn, *args) -> float:
    """Median wall ms over REPS, after a compile/warm-up call."""
    import jax
    jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e3


def _cell(n: int, impl: str, seed: int = 0) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.kernels.cohort_agg import (cohort_agg_divergence,
                                          cohort_agg_divergence_quant)

    q, scales, W, C, staleness = _payload(n, seed)

    # -- unfused reference path: three stages, fp32 stack materialized --
    @jax.jit
    def dequant(q, scales):
        return q.astype(jnp.float32) * scales[:, None, None]

    @jax.jit
    def discount(W, staleness):
        return W * jnp.power(1.0 + staleness, -EXPONENT)[:, None]

    def unfused(q, scales, W, C, staleness):
        deltas = jax.block_until_ready(dequant(q, scales))
        W_eff = jax.block_until_ready(discount(W, staleness))
        return cohort_agg_divergence(deltas, W_eff, C, impl=impl)

    def fused(q, scales, W, C, staleness):
        return cohort_agg_divergence_quant(q, scales, W, C, staleness,
                                           exponent=EXPONENT, impl=impl)

    # numerical cross-check before timing
    for a, b in zip(fused(q, scales, W, C, staleness),
                    unfused(q, scales, W, C, staleness)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)

    unfused_ms = _timeit(unfused, q, scales, W, C, staleness)
    fused_ms = _timeit(fused, q, scales, W, C, staleness)
    int8_mb = n * D * R / 2**20
    return {
        "n": n, "d": D, "r": R, "impl": impl, "exponent": EXPONENT,
        "payload_int8_mb": round(int8_mb, 2),
        "fp32_stack_mb": round(4 * int8_mb, 2),
        "unfused_ms": round(unfused_ms, 3),
        "fused_ms": round(fused_ms, 3),
        "fused_speedup": round(unfused_ms / max(fused_ms, 1e-9), 3),
        "fused_gbps": round(n * D * R / 2**30 / (fused_ms / 1e3), 2),
    }


def run_sweep(smoke: bool = False, seed: int = 0) -> list[dict]:
    rows = []
    ns = (SMOKE_N,) if smoke else NS
    for n in ns:
        rows.append(_cell(n, "xla", seed=seed))
        r = rows[-1]
        print(f"  N={n:>6,d} xla     unfused {r['unfused_ms']:9.2f}ms "
              f"fused {r['fused_ms']:9.2f}ms  "
              f"speedup {r['fused_speedup']:5.2f}x")
    if not smoke:
        # interpret-mode pallas at the smallest N: numerics cross-check only
        rows.append(_cell(64, "pallas", seed=seed))
        r = rows[-1]
        print(f"  N={64:>6,d} pallas  unfused {r['unfused_ms']:9.2f}ms "
              f"fused {r['fused_ms']:9.2f}ms  (interpret — not a perf cell)")
    return rows


def check_regression(rows: list[dict]) -> int:
    """CI gate: N=1024 fused ingest must stay within REGRESSION_FACTOR of
    the committed baseline."""
    if not os.path.exists(BASELINE_PATH):
        print("no committed BENCH_server.json baseline; skipping gate")
        return 0
    with open(BASELINE_PATH) as f:
        base = json.load(f)
    base_row = next((r for r in base.get("rows", [])
                     if r["n"] == SMOKE_N and r["impl"] == "xla"), None)
    cur_row = next((r for r in rows
                    if r["n"] == SMOKE_N and r["impl"] == "xla"), None)
    if base_row is None or cur_row is None:
        print("baseline or current N=1024 row missing; skipping gate")
        return 0
    ceil = base_row["fused_ms"] * REGRESSION_FACTOR
    status = "OK" if cur_row["fused_ms"] <= ceil else "REGRESSION"
    print(f"perf gate: fused {cur_row['fused_ms']:.2f}ms vs baseline "
          f"{base_row['fused_ms']:.2f}ms (ceiling {ceil:.2f}ms) -> {status}")
    return 0 if status == "OK" else 1


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="N=1024 cell only + regression gate (CI)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the committed BENCH_server.json baseline")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rows = run_sweep(smoke=args.smoke, seed=args.seed)
    payload = {"schema_version": SCHEMA_VERSION, "reps": REPS, "rows": rows}
    write_json(os.path.join(RESULTS_DIR, "bench_server_agg.json"), payload)
    if args.update_baseline:
        write_json(os.path.abspath(BASELINE_PATH), payload)
        print(f"baseline written: {os.path.abspath(BASELINE_PATH)}")
    return check_regression(rows)


if __name__ == "__main__":
    raise SystemExit(main())
