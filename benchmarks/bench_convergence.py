"""Fig. 5: macro-F1 vs communication round for five representative methods
(reuses the cached runs from bench_main)."""
from __future__ import annotations

import os

from benchmarks.common import RESULTS_DIR, BenchSpec, run_spec, save_csv

METHODS = ["fedavg", "fedel", "harmony", "relief"]


def run(rounds: int = 30, seed: int = 0, quick: bool = False) -> list[dict]:
    methods = METHODS if not quick else ["fedavg", "relief"]
    if quick:
        rounds = 6
    rows = []
    for backbone in ("b1",):
        for ds in ("pamap2", "mhealth"):
            for m in methods:
                r = run_spec(BenchSpec(m, ds, backbone, rounds, seed))
                for f1, rd in zip(r["f1_curve"], r["f1_rounds"]):
                    rows.append({"backbone": backbone, "dataset": ds,
                                 "method": m, "round": rd, "f1": f1})
    save_csv(rows, os.path.join(RESULTS_DIR, "fig_convergence.csv"),
             ["backbone", "dataset", "method", "round", "f1"])
    # terse terminal view: final few points per curve
    print("\n== Fig. 5 (convergence, final F1 by method) ==")
    seen = {}
    for row in rows:
        seen[(row["backbone"], row["dataset"], row["method"])] = row["f1"]
    for k, v in sorted(seen.items()):
        print(f"  {k[0]} {k[1]:8s} {k[2]:12s} -> {v:.3f}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(a.rounds, quick=a.quick)
