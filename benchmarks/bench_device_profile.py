"""Fig. 8 (real-device study analogue): per-device round breakdown
(compute / comm / idle), power phases, and F1-vs-cumulative-fleet-energy,
under the *forward-aware* timing model (Sec. VII) with the two-Jetson
profile pair (MAXN 60 W vs 15 W mode). Reproduces the paper's finding that
fixed forward cost shrinks the LoRA-backbone speedup (9.41x sim -> ~1.4x
real) while the backward-only reduction survives."""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import RESULTS_DIR, BenchSpec, run_spec


def run(rounds: int = 20, seed: int = 0, quick: bool = False) -> dict:
    if quick:
        rounds = 5
    rounds = min(rounds, 8)
    out = {}
    for backbone in ("b1", "b2"):
        ds = "pamap2" if backbone == "b1" else "mhealth"
        rows = {}
        for mode in ("flop_proportional", "fwd_aware"):
            rows[mode] = {}
            for m in ("fedavg", "relief"):
                r = run_spec(BenchSpec(m, ds, backbone, rounds, seed,
                                       sim_mode=mode))
                rows[mode][m] = {"round_time_s": r["round_time_s"],
                                 "energy_j": r["energy_j"], "f1": r["f1"],
                                 "f1_curve": r["f1_curve"],
                                 "round_times": r["round_times"],
                                 "energy_curve": r["energy_j"]}
        sim_speed = (rows["flop_proportional"]["fedavg"]["round_time_s"]
                     / rows["flop_proportional"]["relief"]["round_time_s"])
        real_speed = (rows["fwd_aware"]["fedavg"]["round_time_s"]
                      / rows["fwd_aware"]["relief"]["round_time_s"])
        out[backbone] = {
            "sim_speedup_flop_proportional": sim_speed,
            "speedup_fwd_aware": real_speed,
            "gap_ratio": sim_speed / max(real_speed, 1e-9),
            "energy_save_pct_fwd_aware": 100 * (
                1 - rows["fwd_aware"]["relief"]["energy_j"]
                / max(rows["fwd_aware"]["fedavg"]["energy_j"], 1e-9)),
        }
        # F1 vs cumulative fleet energy (Fig. 8c/f)
        for m in ("fedavg", "relief"):
            r = run_spec(BenchSpec(m, ds, backbone, rounds, seed,
                                   sim_mode="fwd_aware"))
            cum_e = np.cumsum([r["energy_j"]] * len(r["f1_curve"]))
            out[backbone][f"{m}_f1_at_energy"] = list(
                zip(cum_e.tolist(), r["f1_curve"]))
        print(f"[device_profile:{backbone}] sim {sim_speed:.2f}x vs "
              f"fwd-aware {real_speed:.2f}x (gap {out[backbone]['gap_ratio']:.2f}x), "
              f"energy save {out[backbone]['energy_save_pct_fwd_aware']:.0f}%")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "device_profile.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(a.rounds, quick=a.quick)
