"""Table III: ablation V0 (full RELIEF) / V1 (no elastic) / V2 (no cohort
aggregation) / V3 (random allocation), both backbones, both datasets."""
from __future__ import annotations

import os

from benchmarks.common import (RESULTS_DIR, BenchSpec, fmt_table, run_spec,
                               save_csv)

VARIANTS = ["relief", "v1", "v2", "v3"]  # relief == V0 (cache-shared with bench_main)


def run(rounds: int = 30, seed: int = 0, backbones=("b1",),
        quick: bool = False) -> list[dict]:
    if quick:
        rounds, backbones = 6, ("b1",)
    rows = []
    for backbone in backbones:
        base = run_spec(BenchSpec("fedavg", "pamap2", backbone, rounds, seed))
        for v in VARIANTS:
            row = {"variant": v, "backbone": backbone}
            for ds in ("pamap2", "mhealth"):
                r = run_spec(BenchSpec(v, ds, backbone, rounds, seed))
                row[f"f1_{ds}"] = r["f1"]
                if ds == "pamap2":
                    row["speedup"] = (base["round_time_s"]
                                      / max(r["round_time_s"], 1e-9))
                    row["energy_j"] = r["energy_j"]
            rows.append(row)
    cols = [("variant", "variant"), ("backbone", "backbone"),
            ("PAMAP2 F1", "f1_pamap2"), ("MHEALTH F1", "f1_mhealth"),
            ("Speedup", "speedup"), ("J/r", "energy_j")]
    print(fmt_table(rows, cols, "Table III (ablation)"))
    save_csv(rows, os.path.join(RESULTS_DIR, "table_ablation.csv"),
             [k for _, k in cols])
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(a.rounds, quick=a.quick)
