"""Breakdown-point sweep for Byzantine-robust cohort aggregation.

For each (cohort size K, Byzantine fraction, aggregator) cell, a cohort of
K client deltas is drawn from the real model layout, a seeded fraction is
corrupted through ``sim.faults.FaultModel`` (sign-flip at scale 100 — the
gradient-inversion attack), and the cohort is reduced through the actual
``CohortAggBuffer`` robust path. The figure of merit is the relative L2
error of the aggregate against the honest-only oracle mean:

    rel_err = || agg(corrupted cohort) - mean(honest rows) ||
              / || mean(honest rows) ||

A cell is *bounded* when the median rel_err over trials stays within
``BOUND + BLOWUP x`` the same aggregator's attack-free (byz = 0) error at
that cohort size — breakdown means the error *blows up* relative to the
rule's own noise floor, not that it crosses an absolute line (Krum selects
a single member, so even attack-free it sits O(sqrt K) from the cohort
mean; that is its floor, and it stays there under attack). The plain mean
diverges at any nonzero attacker fraction (error scales with
corruption_scale), trimmed mean holds up to ~trim_frac, and coordinate
median / Krum hold through 40% — the breakdown table in README's
"Adversarial fleets" section.

Outputs
    benchmarks/results/bench_robust.json  full sweep (schema-stable)
    BENCH_robust.json (repo root)         committed baseline, written by
                                          --update-baseline; --smoke runs
                                          the K=8 column only and exits
                                          nonzero if any cell's bounded /
                                          diverged classification flipped
                                          against it (the CI robustness
                                          gate — draws are seeded, so the
                                          classification is deterministic).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import RESULTS_DIR, SCHEMA_VERSION, write_json

BASELINE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                             "BENCH_robust.json")
AGGREGATORS = ("mean", "trimmed", "median", "krum")
BYZ_FRACS = (0.0, 0.1, 0.2, 0.3, 0.4)
COHORT_SIZES = (8, 16, 32)
SMOKE_COHORT = 8
SMOKE_FRACS = (0.0, 0.2, 0.4)
TRIALS = 5
SMOKE_TRIALS = 3
CORRUPTION = "sign_flip"
CORRUPTION_SCALE = 100.0
TRIM_FRAC = 0.25
KRUM_F = 1
BOUND = 2.0  # absolute slack of the boundedness test ...
BLOWUP = 3.0  # ... plus this factor of the aggregator's attack-free error;
# diverged cells land near corruption_scale x byz_frac (>= 10), an order of
# magnitude above any bounded cell's threshold


def _build(seed: int = 0):
    import jax

    from repro.core.tasks import MMTask
    from repro.data import mm_config_for

    cfg = mm_config_for("pamap2", backbone="cnn", d_feat=8, d_fused=32,
                        cnn_ch=(8, 16))
    return MMTask.create(cfg, jax.random.PRNGKey(seed))


def _tree_norm(tree) -> float:
    import jax
    import jax.numpy as jnp
    return float(np.sqrt(sum(float(jnp.sum(jnp.square(x)))
                             for x in jax.tree.leaves(tree))))


def _tree_dist(a, b) -> float:
    import jax
    diff = jax.tree.map(lambda x, y: x - y, a, b)
    return _tree_norm(diff)


def _cell(task, tr0, k: int, byz_frac: float, trials: int,
          seed: int = 0) -> dict[str, list[float]]:
    """-> {aggregator: [rel_err per trial]} for one (K, frac) cohort cell."""
    import jax
    import jax.numpy as jnp

    from repro.core import aggregation as AG
    from repro.core import mdlora
    from repro.sim import FaultModel

    lay = task.layout
    mm = jnp.ones((k, lay.n_modalities))
    trained = jnp.ones((k, lay.G)) * jnp.asarray(lay.sizes > 0)
    W = AG.cohort_weights(lay, trained, mm)
    C = trained
    errs: dict[str, list[float]] = {a: [] for a in AGGREGATORS}
    for t in range(trials):
        key = jax.random.PRNGKey(seed * 1000 + t)
        keys = jax.random.split(key, k)
        deltas = jax.vmap(lambda kk: jax.tree.map(
            lambda x: jax.random.normal(kk, x.shape, jnp.float32),
            tr0))(keys)
        fm = FaultModel(seed=seed * 1000 + t, byzantine_frac=byz_frac,
                        corruption=CORRUPTION,
                        corruption_scale=CORRUPTION_SCALE)
        byz = fm.byzantine_mask(np.ones((k, lay.n_modalities), bool))
        corrupted = fm.corrupt_stack(deltas, byz, np.arange(k),
                                     np.zeros(k, np.int64))
        # honest-only oracle: Eq. 3 cohort mean over the uncorrupted rows
        honest = ~byz
        W_h = AG.cohort_weights(lay, trained[honest], mm[honest])
        oracle = mdlora.weighted_combine(
            lay, jax.tree.map(lambda x: x[honest], deltas), W_h)
        denom = max(_tree_norm(oracle), 1e-9)
        for agg_kind in AGGREGATORS:
            buf = AG.CohortAggBuffer(lay, tr0, robust=agg_kind,
                                     trim_frac=TRIM_FRAC, krum_f=KRUM_F)
            buf.push(corrupted, W, C)
            agg, _, _ = buf.finalize()
            errs[agg_kind].append(_tree_dist(agg, oracle) / denom)
    return errs


def run_sweep(smoke: bool = False, seed: int = 0) -> list[dict]:
    task, tr0 = _build(seed)
    sizes = (SMOKE_COHORT,) if smoke else COHORT_SIZES
    fracs = SMOKE_FRACS if smoke else BYZ_FRACS
    trials = SMOKE_TRIALS if smoke else TRIALS
    rows = []
    for k in sizes:
        cells = {frac: _cell(task, tr0, k, frac, trials, seed)
                 for frac in fracs}
        floor = {a: float(np.median(cells[0.0][a])) for a in AGGREGATORS}
        for frac in fracs:
            errs = cells[frac]
            for agg_kind in AGGREGATORS:
                e = np.asarray(errs[agg_kind])
                med = float(np.median(e))
                rows.append({
                    "cohort_size": k, "byz_frac": frac,
                    "aggregator": agg_kind, "trials": trials,
                    "rel_err_median": round(med, 4),
                    "rel_err_max": round(float(e.max()), 4),
                    "rel_err_clean": round(floor[agg_kind], 4),
                    "bounded": bool(
                        med <= BOUND + BLOWUP * floor[agg_kind]),
                })
            line = "  ".join(
                f"{a}={float(np.median(errs[a])):.3f}" for a in AGGREGATORS)
            print(f"  K={k:2d} byz={frac:4.0%}  " + line)
    return rows


def check_gate(rows: list[dict]) -> int:
    """CI gate, two layers: (1) hard invariant — at >= 20% Byzantine the
    plain mean must have diverged while median/krum stay bounded, and at
    exactly 20% trimmed must hold too (its theoretical breakdown is at
    trim_frac = 25%, so 30-40% cells are covered by the drift gate only);
    (2) every cell's bounded/diverged classification must match the
    committed baseline (seeded draws: deterministic)."""
    rc = 0
    for r in rows:
        if r["byz_frac"] < 0.2 - 1e-9:
            continue
        if r["aggregator"] == "trimmed" and r["byz_frac"] > 0.2 + 1e-9:
            continue
        want_bounded = r["aggregator"] != "mean"
        if r["bounded"] != want_bounded:
            print(f"INVARIANT FAIL: K={r['cohort_size']} "
                  f"byz={r['byz_frac']:.0%} {r['aggregator']} "
                  f"bounded={r['bounded']} (expected {want_bounded})")
            rc = 1
    if not os.path.exists(BASELINE_PATH):
        print("no committed BENCH_robust.json baseline; skipping drift gate")
        return rc
    with open(BASELINE_PATH) as f:
        base = json.load(f)
    bkey = {(r["cohort_size"], r["byz_frac"], r["aggregator"]): r["bounded"]
            for r in base.get("rows", [])}
    for r in rows:
        k = (r["cohort_size"], r["byz_frac"], r["aggregator"])
        if k in bkey and bkey[k] != r["bounded"]:
            print(f"BASELINE DRIFT: {k} bounded {bkey[k]} -> {r['bounded']}")
            rc = 1
    print("robustness gate:", "OK" if rc == 0 else "FAIL")
    return rc


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="K=8 column only + classification gate (CI)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the committed BENCH_robust.json baseline")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rows = run_sweep(smoke=args.smoke, seed=args.seed)
    payload = {"schema_version": SCHEMA_VERSION, "corruption": CORRUPTION,
               "corruption_scale": CORRUPTION_SCALE, "trim_frac": TRIM_FRAC,
               "krum_f": KRUM_F, "bound": BOUND, "rows": rows}
    write_json(os.path.join(RESULTS_DIR, "bench_robust.json"), payload)
    if args.update_baseline:
        write_json(os.path.abspath(BASELINE_PATH), payload)
        print(f"baseline written: {os.path.abspath(BASELINE_PATH)}")
    return check_gate(rows)


if __name__ == "__main__":
    raise SystemExit(main())
