"""Scenario-matrix benchmark: missing-modality generators x strategies.

Sweeps the scenario library (sim/scenarios.py) over protocol strategies on
the heap async runtime — every cell of a (scenario, strategy) pair shares
the same seeded fleet, dataset and dispatch schedule, so differences are
attributable to the strategy alone. The default matrix runs the paper's
RELIEF allocation (async_relief), the FedAvg-style async baseline
(async_fedbuff), the accessible-allocation control (async_accessible),
and the FedMFS-style selective-communication strategy (fedmfs_selective,
arXiv:2310.07048) across static 10/30/50% missing, tier-correlated, and
time-varying streaming scenarios. The headline check: selective uploads
strictly fewer bytes than its non-selective twin (async_accessible — same
training, same dispatch) at comparable final F1.

Outputs
    benchmarks/results/bench_scenarios.json   full matrix (schema-stable)
    BENCH_scenarios.json (repo root)          committed baseline, written by
                                              --update-baseline; --smoke runs
                                              the mini-matrix
                                              (static30, stream30) x
                                              (async_relief,
                                              async_accessible,
                                              fedmfs_selective)
                                              and exits nonzero if the
                                              selective-upload invariant or
                                              the baseline tolerances break
                                              (the CI scenario gate).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, SCHEMA_VERSION, write_json

BASELINE_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                             "BENCH_scenarios.json")

SCENARIO_NAMES = ("static10", "static30", "static50", "tiered30", "stream30")
METHODS = ("async_relief", "async_fedbuff", "async_accessible",
           "fedmfs_selective")
SMOKE_SCENARIOS = ("static30", "stream30")
SMOKE_METHODS = ("async_relief", "async_accessible", "fedmfs_selective")

# gate tolerances: uploads are seeded-deterministic (tight); F1 on tiny
# smoke runs moves with BLAS/JAX versions (loose, absolute)
UPLOAD_REL_TOL = 1.5
F1_ABS_TOL = 0.15


def _cell(scenario: str, method: str, total_updates: int,
          windows: int, seed: int) -> dict:
    from repro.sim import get_scenario, make_run

    spec = get_scenario(
        scenario, strategy=method, seed=seed, windows_per_subject=windows,
        local_epochs=1, steps_per_epoch=2, batch_size=16, eval_every=0,
        total_updates=total_updates)
    run, sc = make_run(spec)
    t0 = time.perf_counter()
    hist = run.run(sc.dataset)
    wall = time.perf_counter() - t0
    return {
        "scenario": scenario, "method": method,
        "missing": spec.missing, "missing_ratio": spec.missing_ratio,
        "f1": round(float(hist["f1"][-1]), 4),
        "upload_mb": round(float(run.trace.upload_mb), 6),
        "sim_time_s": round(float(run.state.sim_time), 4),
        "flushes": int(run.trace.flushes),
        "staleness_mean": round(float(np.mean(hist["staleness_mean"])), 3),
        "selected_frac": round(float(np.mean(hist["selected_frac"])), 4),
        "wall_s": round(wall, 3),
    }


def run_matrix(smoke: bool = False, total_updates: int = 48,
               windows: int = 60, seed: int = 0) -> list[dict]:
    scenarios = SMOKE_SCENARIOS if smoke else SCENARIO_NAMES
    methods = SMOKE_METHODS if smoke else METHODS
    rows = []
    for scenario in scenarios:
        for method in methods:
            row = _cell(scenario, method, total_updates, windows, seed)
            rows.append(row)
            print(f"  {scenario:10s} {method:18s} F1 {row['f1']:.3f} "
                  f"up {row['upload_mb']:8.4f}MB sel {row['selected_frac']:.2f} "
                  f"wall {row['wall_s']:6.1f}s")
    return rows


def _by_key(rows: list[dict]) -> dict[tuple[str, str], dict]:
    return {(r["scenario"], r["method"]): r for r in rows}


def check_gate(rows: list[dict]) -> int:
    """CI gate, two parts: (1) hard invariant — fedmfs_selective is
    async_accessible plus the selective uploader (identical training and
    dispatch), so it must upload strictly fewer bytes on every shared
    scenario; (2) committed BENCH_scenarios.json tolerances on upload
    volume and final F1."""
    failures = []
    cur = _by_key(rows)
    for (scenario, method), row in cur.items():
        if method != "fedmfs_selective":
            continue
        ref = cur.get((scenario, "async_accessible"))
        if ref is None:
            continue
        if row["upload_mb"] >= ref["upload_mb"]:
            failures.append(
                f"{scenario}: selective uploaded {row['upload_mb']:.4f}MB "
                f">= accessible {ref['upload_mb']:.4f}MB")
        else:
            print(f"selective gate: {scenario} {row['upload_mb']:.4f}MB < "
                  f"{ref['upload_mb']:.4f}MB OK "
                  f"(dF1 {row['f1'] - ref['f1']:+.3f})")

    if not os.path.exists(BASELINE_PATH):
        print("no committed BENCH_scenarios.json baseline; skipping "
              "tolerance gate")
    else:
        with open(BASELINE_PATH) as f:
            base = _by_key(json.load(f).get("rows", []))
        for key, row in cur.items():
            ref = base.get(key)
            if ref is None:
                continue
            lo = ref["upload_mb"] / UPLOAD_REL_TOL
            hi = ref["upload_mb"] * UPLOAD_REL_TOL
            if not lo <= row["upload_mb"] <= hi:
                failures.append(
                    f"{key}: upload {row['upload_mb']:.4f}MB outside "
                    f"[{lo:.4f}, {hi:.4f}] of baseline")
            if row["f1"] < ref["f1"] - F1_ABS_TOL:
                failures.append(
                    f"{key}: F1 {row['f1']:.3f} < baseline "
                    f"{ref['f1']:.3f} - {F1_ABS_TOL}")
        print(f"baseline gate: {len(cur)} rows checked against "
              f"{os.path.basename(BASELINE_PATH)}")

    for msg in failures:
        print(f"GATE FAIL: {msg}")
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="2x2 mini-matrix + gate (CI)")
    ap.add_argument("--total-updates", type=int, default=48,
                    help="absorbed client completions per cell")
    ap.add_argument("--windows", type=int, default=60,
                    help="windows per subject (dataset size)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the committed BENCH_scenarios.json baseline")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rows = run_matrix(smoke=args.smoke, total_updates=args.total_updates,
                      windows=args.windows, seed=args.seed)
    payload = {"schema_version": SCHEMA_VERSION,
               "total_updates": args.total_updates, "windows": args.windows,
               "rows": rows}
    write_json(os.path.join(RESULTS_DIR, "bench_scenarios.json"), payload)
    if args.update_baseline:
        write_json(os.path.abspath(BASELINE_PATH), payload)
        print(f"baseline written: {os.path.abspath(BASELINE_PATH)}")
    return check_gate(rows)


if __name__ == "__main__":
    raise SystemExit(main())
