"""Figs. 2-3 (motivational studies): run FedAvg on the heterogeneous fleet
and measure (a) pairwise cosine similarity of fusion-block updates between
device pairs grouped by modality block, and (b) per-block cohort-internal
divergence across training phases — reproducing Observation 1 (interference
reaches shared blocks) and Observation 2 (rare-modality divergence grows)."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR
from repro.core import mdlora
from repro.core.engine import FedConfig, FedRun
from repro.core import strategies
from repro.core.tasks import MMTask
from repro.data import make_har_dataset, mm_config_for
from repro.sim import make_fleet


def block_cosines(deltas, layout, pairs):
    """Per-block cosine similarity of the fusion-leaf update between client
    pairs. -> {block_name: [cos per pair]}"""
    leaves = jax.tree_util.tree_flatten_with_path(deltas)[0]
    fusion = next(l for p, l in leaves
                  if mdlora.path_str(p) == layout.fusion_a_path)  # [N, D, r]
    out = {}
    for s, e, g in layout.fusion_rows:
        name = layout.names[g]
        cs = []
        for i, j in pairs:
            a = np.asarray(fusion[i, s:e]).ravel()
            b = np.asarray(fusion[j, s:e]).ravel()
            na, nb = np.linalg.norm(a), np.linalg.norm(b)
            cs.append(float(a @ b / (na * nb)) if na > 1e-12 and nb > 1e-12
                      else 0.0)
        out[name] = cs
    return out


def run(rounds: int = 24, seed: int = 0, quick: bool = False,
        force: bool = False) -> dict:
    cache = os.path.join(RESULTS_DIR, "motivation.json")
    if os.path.exists(cache) and not force:
        with open(cache) as f:
            out = json.load(f)
        print("[bench_motivation] cached motivation.json found — skipping "
              "re-run (pass force=True to redo)")
        return out
    if quick:
        rounds = 6
    ds = make_har_dataset("pamap2", windows_per_subject=160, seed=seed)
    fleet = make_fleet(3, 3, 2, M=4)
    cfg = mm_config_for("pamap2", backbone="cnn", d_feat=16, d_fused=64,
                        cnn_ch=(16, 32))
    task, tr0 = MMTask.create(cfg, jax.random.PRNGKey(seed))
    fed = FedConfig(rounds=rounds, eval_every=rounds,
                    local_epochs=2, steps_per_epoch=4, seed=seed)
    run_ = FedRun.create(task, tr0, strategies.get("fedavg"), fleet, fed)

    # instrument: capture per-round deltas + divergence phases
    full_pairs = [(0, 1), (0, 2), (1, 2)]  # Full-Full
    cross_pairs = [(0, 6), (1, 7), (2, 6)]  # Full vs Acc-only
    layout = task.layout
    phase_div = []
    cos_records = {"full_full": [], "full_acconly": []}

    batches_fn = run_._round_batches
    orig_round = run_.round

    # monkey-light instrumentation: recompute deltas each round via the
    # engine's own local_update on the same data
    for r in range(rounds):
        state = run_.state
        batches = batches_fn(ds)
        gates = jnp.ones((fleet.N, layout.G))
        start = run_._start_trainable()
        deltas, _ = run_.local_update(
            start, batches, jnp.asarray(fleet.modality_mask, jnp.float32),
            gates, run_.rank_gate, fed.lr)
        cos_full = block_cosines(deltas, layout, full_pairs)
        cos_cross = block_cosines(deltas, layout, cross_pairs)
        cos_records["full_full"].append(cos_full)
        cos_records["full_acconly"].append(cos_cross)
        rec = orig_round(ds)
        phase_div.append(np.asarray(rec["divergence"]).tolist())

    # aggregate: mean cosine per block per pair type (Fig. 2). Early rounds
    # carry the shared descent direction (late-round deltas are converged
    # noise), so we average rounds 1..5 like the paper's early phase.
    fig2 = {}
    for pt, recs in cos_records.items():
        fig2[pt] = {blk: float(np.mean([np.mean(r[blk]) for r in recs[:5]]))
                    for blk in recs[0]}
    # divergence phases (Fig. 3): split rounds into 5 phases
    d = np.asarray(phase_div)  # [R, G]
    phases = np.array_split(d, min(5, len(d)))
    fusion_ids = layout.group_ids(mdlora.KIND_FUSION_BLOCK)
    fig3 = {layout.names[g]: [float(p[:, g].mean()) for p in phases]
            for g in fusion_ids}

    out = {"fig2_block_cosine": fig2, "fig3_divergence_phases": fig3}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "motivation.json"), "w") as f:
        json.dump(out, f, indent=1)

    print("\n== Fig. 2: mean update cosine by block (late rounds) ==")
    print(f"{'block':10s} {'Full-Full':>10s} {'Full-AccOnly':>13s}")
    for blk in fig2["full_full"]:
        print(f"{blk:10s} {fig2['full_full'][blk]:10.3f} "
              f"{fig2['full_acconly'][blk]:13.3f}")
    print("\n== Fig. 3: fusion-block divergence by phase ==")
    for blk, vals in fig3.items():
        print(f"{blk:10s} " + " ".join(f"{v:.4f}" for v in vals))
    growth = {b: (v[-1] / max(v[0], 1e-12)) for b, v in fig3.items()}
    print("growth (last/first):", {b: round(g, 2) for b, g in growth.items()})
    # Observation-2 (relative form): rare-block divergence persists while the
    # common block's decays — the ratio d_rare/d_acc grows over training.
    ratios = [fig3["A_mag"][i] / max(fig3["A_acc"][i], 1e-12)
              for i in range(len(fig3["A_acc"]))]
    out["obs2_rare_to_common_ratio"] = ratios
    print("d(Mag)/d(Acc) by phase:", [round(r, 3) for r in ratios])
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(a.rounds, quick=a.quick)
