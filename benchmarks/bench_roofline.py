"""§Roofline: aggregate the dry-run JSONs into the per-(arch x shape x mesh)
roofline table (three terms, dominant bottleneck, MODEL_FLOPS ratio)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, fmt_table, save_csv

DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")


def load_cells(pattern: str = "*.json") -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def run(mesh: str = "single") -> list[dict]:
    rows = []
    for c in load_cells():
        if c.get("mesh") != mesh or c.get("hillclimb"):
            continue
        if c["status"] == "skipped":
            rows.append({"arch": c["arch"], "shape": c["shape"],
                         "dominant": "N/A (skip)", "note": c["reason"][:40]})
            continue
        if c["status"] != "ok":
            rows.append({"arch": c["arch"], "shape": c["shape"],
                         "dominant": "ERROR"})
            continue
        if "roofline" not in c:  # multi-pod compile+memory-only pass
            rows.append({"arch": c["arch"], "shape": c["shape"],
                         "dominant": "(compiled)",
                         "mem_gb": c.get("memory", {}).get("per_device_gb",
                                                           -1),
                         "fits": c.get("memory", {}).get("fits_16gb_hbm")})
            continue
        r = c["roofline"]
        t_dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        rows.append({
            "arch": c["arch"], "shape": c["shape"],
            "t_compute_s": r["t_compute_s"], "t_memory_s": r["t_memory_s"],
            "t_collective_s": r["t_collective_s"], "dominant": r["dominant"],
            "roofline_frac": r["t_compute_s"] / max(t_dom, 1e-12),
            "useful_flops_ratio": c.get("useful_flops_ratio", 0.0),
            "mem_gb": c.get("memory", {}).get("per_device_gb", -1),
            "fits": c.get("memory", {}).get("fits_16gb_hbm", None),
        })
    cols = [("arch", "arch"), ("shape", "shape"), ("tc(s)", "t_compute_s"),
            ("tm(s)", "t_memory_s"), ("tx(s)", "t_collective_s"),
            ("dom", "dominant"), ("roofline%", "roofline_frac"),
            ("useful%", "useful_flops_ratio"), ("GB/dev", "mem_gb"),
            ("fits", "fits")]
    print(fmt_table(rows, cols, f"Roofline table ({mesh}-pod)"))
    save_csv(rows, os.path.join(RESULTS_DIR, f"roofline_{mesh}.csv"),
             [k for _, k in cols])
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    run(ap.parse_args().mesh)
