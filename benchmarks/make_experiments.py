"""Regenerate the auto sections of EXPERIMENTS.md from result artifacts.

Usage: PYTHONPATH=src python -m benchmarks.make_experiments
Replaces text between <!--AUTO:name--> ... <!--/AUTO:name--> markers.
"""
from __future__ import annotations

import glob
import json
import os
import re

RES = os.path.join(os.path.dirname(__file__), "results")
EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")


def dryrun_table(mesh: str) -> str:
    rows = []
    for f in sorted(glob.glob(os.path.join(RES, "dryrun",
                                           f"*__{mesh}.json"))):
        d = json.load(open(f))
        if d.get("hillclimb"):
            continue
        if d["status"] == "skipped":
            rows.append(f"| {d['arch']} | {d['shape']} | SKIP (full attn; "
                        f"DESIGN §4) | | | | | | |")
            continue
        if d["status"] != "ok":
            rows.append(f"| {d['arch']} | {d['shape']} | ERROR | | | | | | |")
            continue
        r, m = d["roofline"], d.get("memory", {})
        tdom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"],
                   1e-12)
        rows.append(
            f"| {d['arch']} | {d['shape']} | {r['dominant']} "
            f"| {r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} "
            f"| {r['t_collective_s']:.3f} | {r['t_compute_s'] / tdom:.2f} "
            f"| {d.get('useful_flops_ratio', 0):.2f} "
            f"| {m.get('per_device_gb', '-')} "
            f"{'OK' if m.get('fits_16gb_hbm') else 'OVER'} |")
    head = ("| arch | shape | dominant | t_compute(s) | t_memory(s) | "
            "t_collective(s) | roofline-frac | useful-FLOPs | GB/dev |\n"
            "|---|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def compile_stats(mesh: str) -> str:
    n_ok = n_skip = n_err = 0
    fits = 0
    for f in glob.glob(os.path.join(RES, "dryrun", f"*__{mesh}.json")):
        d = json.load(open(f))
        if d.get("hillclimb"):
            continue
        if d["status"] == "ok":
            n_ok += 1
            fits += bool(d.get("memory", {}).get("fits_16gb_hbm"))
        elif d["status"] == "skipped":
            n_skip += 1
        else:
            n_err += 1
    return (f"{n_ok} cells compiled, {n_skip} N/A-by-design (long_500k on "
            f"pure full-attention archs), {n_err} errors; {fits}/{n_ok} "
            f"within the 16 GB/chip HBM budget (donation-adjusted).")


def bench_csv(name: str) -> str:
    path = os.path.join(RES, name)
    if not os.path.exists(path):
        return f"(pending: {name})"
    with open(path) as f:
        lines = [l.strip() for l in f if l.strip()]
    head = "| " + " | ".join(lines[0].split(",")) + " |"
    sep = "|" + "---|" * len(lines[0].split(","))
    body = []
    for l in lines[1:]:
        cells = []
        for c in l.split(","):
            try:
                cells.append(f"{float(c):.3f}")
            except ValueError:
                cells.append(c)
        body.append("| " + " | ".join(cells) + " |")
    return "\n".join([head, sep] + body)


def motivation() -> str:
    path = os.path.join(RES, "motivation.json")
    if not os.path.exists(path):
        return "(pending)"
    d = json.load(open(path))
    out = ["**Fig. 2 (block-cosine, early rounds):**", "",
           "| block | Full–Full | Full–AccOnly |", "|---|---|---|"]
    for blk in d["fig2_block_cosine"]["full_full"]:
        out.append(f"| {blk} | {d['fig2_block_cosine']['full_full'][blk]:.3f}"
                   f" | {d['fig2_block_cosine']['full_acconly'][blk]:.3f} |")
    out += ["", "**Fig. 3 (fusion-block divergence by phase):**", "",
            "| block | p1 | p2 | p3 | p4 | p5 |", "|---|---|---|---|---|---|"]
    for blk, vals in d["fig3_divergence_phases"].items():
        out.append("| " + blk + " | "
                   + " | ".join(f"{v:.4f}" for v in vals) + " |")
    if "obs2_rare_to_common_ratio" in d:
        out += ["", "d(Mag)/d(Acc) per phase: "
                + ", ".join(f"{r:.2f}" for r in
                            d["obs2_rare_to_common_ratio"])]
    return "\n".join(out)


def device_profile() -> str:
    path = os.path.join(RES, "device_profile.json")
    if not os.path.exists(path):
        return "(pending)"
    d = json.load(open(path))
    out = ["| backbone | sim speedup (FLOP-prop) | fwd-aware speedup | "
           "gap | energy save (fwd-aware) |", "|---|---|---|---|---|"]
    for b, v in d.items():
        out.append(f"| {b} | {v['sim_speedup_flop_proportional']:.2f}x "
                   f"| {v['speedup_fwd_aware']:.2f}x | {v['gap_ratio']:.2f}x "
                   f"| {v['energy_save_pct_fwd_aware']:.0f}% |")
    return "\n".join(out)


SECTIONS = {
    "dryrun_single": lambda: dryrun_table("single"),
    "dryrun_multi": lambda: dryrun_table("multi"),
    "compile_single": lambda: compile_stats("single"),
    "compile_multi": lambda: compile_stats("multi"),
    "table_main_b1": lambda: bench_csv("table_main_b1.csv"),
    "table_main_b2": lambda: bench_csv("table_main_b2.csv"),
    "table_ablation": lambda: bench_csv("table_ablation.csv"),
    "table_sensitivity": lambda: bench_csv(
        "table_sensitivity_pamap2_b1.csv"),
    "motivation": motivation,
    "device_profile": device_profile,
    "permodality": lambda: bench_csv("fig_permodality.csv"),
}


def main():
    with open(EXP) as f:
        text = f.read()
    for name, fn in SECTIONS.items():
        marker = f"<!--AUTO:{name}-->"
        end = f"<!--/AUTO:{name}-->"
        if marker not in text:
            continue
        try:
            content = fn()
        except Exception as e:  # noqa: BLE001
            content = f"(generation failed: {e})"
        pattern = re.escape(marker) + r".*?" + re.escape(end)
        text = re.sub(pattern, marker + "\n" + content + "\n" + end, text,
                      flags=re.S)
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md regenerated")


if __name__ == "__main__":
    main()
