"""Shared benchmark harness: one federated run -> one metrics row.

Runs are cached as JSON under benchmarks/results/runs/ keyed by their full
configuration, so every bench script (main tables, ablation, sensitivity,
convergence, per-modality) reuses the same underlying runs and the suite is
resumable after interruption.

Scale note (DESIGN.md §7): default configs are reduced-but-faithful (same
fleet topology, compute-gap and protocol as the paper; smaller models and
fewer rounds for the 1-core CPU container). ``--full`` restores paper scale.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# bump when the shape of any emitted JSON changes — CI artifact consumers
# (and the run cache) key on this
SCHEMA_VERSION = 1


def write_json(path: str, payload: dict) -> None:
    """Schema-stable JSON emission: every document carries schema_version
    and sorted keys, so artifact diffs are meaningful across CI runs."""
    payload.setdefault("schema_version", SCHEMA_VERSION)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)

RARE_MODALITIES = {"pamap2": ("mag", "hr"), "mhealth": ("mag", "ecg")}

# method display names / citations (paper Tables I-II rows)
METHOD_LABELS = {
    "fedavg": "FedAvg [AISTATS'17]", "fedprox": "FedProx [MLSys'20]",
    "fedel": "FedEL* [NeurIPS'25]", "fedicu": "FedICU* [ICML'25]",
    "darkdistill": "DarkDistill* [KDD'25]", "harmony": "Harmony* [MobiSys'23]",
    "pilot": "Pilot* [AAAI'25]", "fedsa_lora": "FedSA-LoRA* [ICLR'25]",
    "helora": "HeLoRA* [TOIT'25]", "fedlease": "FedLEASE* [NeurIPS'25]",
    "relief": "RELIEF (ours)", "v0": "RELIEF (V0)",
    "v1": "V1 w/o elastic", "v2": "V2 w/o cohort agg", "v3": "V3 random alloc",
}  # * = protocol-level reimplementation (see core/strategies.py docstrings)


@dataclasses.dataclass(frozen=True)
class BenchSpec:
    method: str
    dataset: str = "pamap2"
    backbone: str = "b1"  # b1 (CNN) | b2 (frozen transformer + LoRA)
    rounds: int = 30
    seed: int = 0
    hetero_scale: float | None = None  # None = profile default (55x)
    n_clients: int | None = None  # None = paper fleet (8 / 10)
    sim_mode: str = "flop_proportional"
    windows: int = 160
    small: bool = True  # reduced model configs

    def key(self) -> str:
        blob = json.dumps(dataclasses.asdict(self), sort_keys=True)
        return (f"{self.method}_{self.dataset}_{self.backbone}_r{self.rounds}"
                f"_s{self.seed}_" + hashlib.md5(blob.encode()).hexdigest()[:8])


def _build(spec: BenchSpec):
    """BenchSpec -> (FedRun, dataset, task) through the scenario API: one
    ScenarioSpec carries the fleet/model/training knobs (model-size presets
    live in data/registry.py, config blocks in FedConfig.from_scenario)."""
    import jax

    from repro.core import strategies
    from repro.core.engine import FedConfig, FedRun
    from repro.core.tasks import MMTask
    from repro.data import get_provider
    from repro.sim import ScenarioSpec, build_fleet

    sspec = ScenarioSpec(
        name=spec.key(), dataset=spec.dataset, missing="none",
        windows_per_subject=spec.windows,
        fleet=(3, 3, 2 if spec.dataset == "pamap2" else 4),
        n_clients=spec.n_clients, hetero_scale=spec.hetero_scale,
        strategy=spec.method,
        backbone="cnn" if spec.backbone == "b1" else "transformer",
        small_model=spec.small, rounds=spec.rounds,
        eval_every=max(spec.rounds // 10, 1), t_overhead=0.1,
        utilization=2e-5, seed=spec.seed)
    provider = get_provider(spec.dataset)
    fleet = build_fleet(sspec)
    ds = provider.build(seed=spec.seed, n_clients=fleet.N,
                        windows_per_subject=spec.windows)
    cfg = provider.mm_config(sspec.backbone, small=spec.small)
    task, tr0 = MMTask.create(cfg, jax.random.PRNGKey(spec.seed))
    fed = FedConfig.from_scenario(sspec, sim_mode=spec.sim_mode)
    run = FedRun.create(task, tr0, strategies.get(spec.method), fleet, fed)
    return run, ds, task


def run_spec(spec: BenchSpec, force: bool = False, verbose: bool = True) -> dict:
    """Execute (or load cached) one federated benchmark run -> metrics dict."""
    os.makedirs(os.path.join(RESULTS_DIR, "runs"), exist_ok=True)
    cache = os.path.join(RESULTS_DIR, "runs", spec.key() + ".json")
    if os.path.exists(cache) and not force:
        with open(cache) as f:
            cached = json.load(f)
        if cached.get("schema_version") == SCHEMA_VERSION:
            return cached
        # schema drift: fall through and re-run so consumers never see a
        # mixed-version document

    from repro.core import metrics as M

    run, ds, task = _build(spec)
    hist = run.run(ds, log_every=0)

    xs = np.concatenate(ds.test_x)
    ys = np.concatenate(ds.test_y)
    per_mod = task.eval_per_modality(run.state.trainable, xs, ys)
    rare = M.rare_modality_f1(per_mod, RARE_MODALITIES[spec.dataset])
    out = {
        "schema_version": SCHEMA_VERSION,
        "spec": dataclasses.asdict(spec),
        "f1": hist["f1"][-1],
        "f1_curve": hist["f1"],
        "f1_rounds": hist["f1_round"],
        "per_modality_f1": per_mod,
        "rare_mod_f1": rare,
        "round_time_s": float(np.mean(hist["round_time_s"])),
        "round_times": hist["round_time_s"],
        "energy_j": float(np.mean(hist["energy_j"])),
        "upload_mb": float(np.mean(hist["upload_mb"])),
        "loss_curve": hist["loss"],
        "divergence_final": np.asarray(hist["divergence"][-1]).tolist(),
        "divergence_curves": np.asarray(hist["divergence"]).tolist(),
        "group_names": task.layout.names,
        "selected_frac": float(np.mean(hist["selected_frac"])),
    }
    with open(cache, "w") as f:
        json.dump(out, f)
    if verbose:
        print(f"  [{spec.method:12s}] F1 {out['f1']:.3f} rare {rare:.3f} "
              f"t/r {out['round_time_s']:.2f}s E/r {out['energy_j']:.0f}J "
              f"{out['upload_mb']:.2f}MB")
    return out


def tta_rounds(f1_curve, f1_rounds, threshold: float):
    for f, r in zip(f1_curve, f1_rounds):
        if f >= threshold:
            return r
    return None


def fmt_table(rows: list[dict], columns: list[tuple[str, str]],
              title: str) -> str:
    lines = [f"\n== {title} ==",
             " | ".join(h for h, _ in columns),
             "-|-".join("-" * len(h) for h, _ in columns)]
    for row in rows:
        cells = []
        for _, k in columns:
            v = row.get(k, "")
            cells.append(f"{v:.3f}" if isinstance(v, float) else str(v))
        lines.append(" | ".join(cells))
    return "\n".join(lines)


def save_csv(rows: list[dict], path: str, fields: list[str]) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(",".join(fields) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(k, "")) for k in fields) + "\n")
