"""Fault-tolerant checkpointing for pytrees + server state.

Design (multi-host-safe layout, single-host implementation here):
  * every save goes to ``<dir>/tmp.<step>.<nonce>/`` then is atomically
    renamed to ``<dir>/step_<step>/`` — a crash mid-save never corrupts the
    latest checkpoint (restore only ever sees complete directories);
  * arrays are stored as one ``.npz`` per shard-owner (here: one) plus a
    JSON manifest with the treedef, dtypes, and user metadata (round index,
    divergence EMA, rng state, strategy name);
  * ``keep``-newest retention, ``latest_step()``/``restore_latest()`` resume.

On a real multi-pod deployment each host writes only the shards it owns
(process-local addressable shards) and host 0 writes the manifest; the
directory protocol is unchanged — this is the standard Orbax-style layout.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any

import jax
import numpy as np


_NPZ_SAFE = {"float64", "float32", "float16", "int64", "int32", "int16",
             "int8", "uint64", "uint32", "uint16", "uint8", "bool"}


def _to_npz(a: np.ndarray) -> np.ndarray:
    """np.savez cannot serialize ml_dtypes (bfloat16 etc.) — store the raw
    bits; the manifest dtype restores them."""
    if a.dtype.name not in _NPZ_SAFE:
        return a.view(np.uint8 if a.dtype.itemsize == 1 else
                      np.uint16 if a.dtype.itemsize == 2 else np.uint32)
    return a


def _flatten(tree: Any) -> tuple[list[np.ndarray], Any, list[str]]:
    leaves_p = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [np.asarray(x) for _, x in leaves_p[0]]
    paths = [jax.tree_util.keystr(p) for p, _ in leaves_p[0]]
    return leaves, leaves_p[1], paths


def save_tree(path: str, tree: Any, metadata: dict | None = None) -> None:
    """Atomic save of one pytree + metadata into directory ``path``."""
    parent = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}.{int(time.time()*1e6)}"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef, paths = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"leaf_{i}": _to_npz(a) for i, a in enumerate(leaves)})
    manifest = {
        "paths": paths,
        "dtypes": [str(a.dtype) for a in leaves],
        "shapes": [list(a.shape) for a in leaves],
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def restore_tree(path: str, like: Any) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (dtype-cast to match)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    import ml_dtypes
    leaves = []
    for i, dt in enumerate(manifest["dtypes"]):
        raw = data[f"leaf_{i}"]
        if dt not in _NPZ_SAFE:
            raw = raw.view(np.dtype(getattr(ml_dtypes, dt)))
        leaves.append(raw)
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(like_leaves) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, target expects "
            f"{len(like_leaves)} — structure mismatch")
    import jax.numpy as jnp
    restored = [jnp.asarray(a, dtype=l.dtype) for a, l in
                zip(leaves, like_leaves)]
    return jax.tree_util.tree_unflatten(treedef, restored), manifest["metadata"]


class CheckpointManager:
    """step-indexed checkpoints with retention + resume."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree: Any, metadata: dict | None = None) -> str:
        meta = dict(metadata or {})
        meta["step"] = step
        p = self._step_dir(step)
        save_tree(p, tree, meta)
        for old in self.steps()[: -self.keep]:
            shutil.rmtree(self._step_dir(old), ignore_errors=True)
        return p

    def restore(self, step: int, like: Any) -> tuple[Any, dict]:
        return restore_tree(self._step_dir(step), like)

    def restore_latest(self, like: Any) -> tuple[Any, dict] | None:
        s = self.latest_step()
        if s is None:
            return None
        return self.restore(s, like)
