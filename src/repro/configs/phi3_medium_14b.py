"""phi3-medium-14b [arXiv:2404.14219]: 40L, d=5120, 40H (GQA kv=10),
d_ff=17920, vocab=100352 — RoPE + SwiGLU + GQA decoder."""
from repro.configs.base import (ModelConfig, ShapeConfig, lm_input_specs,
                                register, supports)
import sys

FULL = ModelConfig(
    arch="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=10, head_dim=128, d_ff=17920, vocab=100352,
    activation="silu", rope_theta=10000.0, tie_embeddings=False,
    dtype="bfloat16", param_dtype="bfloat16", q_chunk=1024, remat="dots",
)

SMOKE = ModelConfig(
    arch="phi3-medium-14b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160, vocab=128,
    tie_embeddings=False, dtype="float32", param_dtype="float32",
    remat="none", q_chunk=32,
)


def input_specs(shape: ShapeConfig, cfg: ModelConfig = FULL) -> dict:
    return lm_input_specs(cfg, shape)


register("phi3-medium-14b", sys.modules[__name__])
