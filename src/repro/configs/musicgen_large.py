"""musicgen-large [arXiv:2306.05284]: 48L, d=2048, 32H (MHA kv=32),
d_ff=8192, vocab=2048 per codebook — decoder-only over 4 parallel EnCodec
codebook streams (delay pattern). The EnCodec audio frontend is a STUB per
the assignment: the backbone consumes codebook token ids; each codebook's
embedding stream is an MDLoRA modality block."""
import sys

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, ShapeConfig, register)

FULL = ModelConfig(
    arch="musicgen-large", family="audio", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, head_dim=64, d_ff=8192, vocab=2048,
    n_codebooks=4, activation="gelu", tie_embeddings=False,
    dtype="bfloat16", param_dtype="bfloat16", q_chunk=1024, remat="dots",
)

SMOKE = ModelConfig(
    arch="musicgen-large-smoke", family="audio", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab=33, n_codebooks=4,
    activation="gelu", tie_embeddings=False, dtype="float32",
    param_dtype="float32", remat="none", q_chunk=32,
)


def input_specs(shape: ShapeConfig, cfg: ModelConfig = FULL) -> dict:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    CB = cfg.n_codebooks
    if shape.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((B, S, CB), i32),
                "labels": jax.ShapeDtypeStruct((B, S, CB), i32)}
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((B, S, CB), i32)}
    return {"token": jax.ShapeDtypeStruct((B, 1, CB), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


register("musicgen-large", sys.modules[__name__])
