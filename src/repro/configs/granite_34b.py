"""granite-34b [arXiv:2405.04324]: 88L, d=6144, 48H (MQA kv=1), d_ff=24576,
vocab=49152 — llama-style code model with multi-query attention."""
from repro.configs.base import (ModelConfig, ShapeConfig, lm_input_specs,
                                register)
import sys

FULL = ModelConfig(
    arch="granite-34b", family="dense", n_layers=88, d_model=6144, n_heads=48,
    n_kv_heads=1, head_dim=128, d_ff=24576, vocab=49152, activation="gelu",
    tie_embeddings=True, dtype="bfloat16", param_dtype="bfloat16",
    q_chunk=1024, remat="dots",
)

SMOKE = ModelConfig(
    arch="granite-34b-smoke", family="dense", n_layers=3, d_model=64,
    n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab=96,
    activation="gelu", dtype="float32", param_dtype="float32", remat="none",
    q_chunk=32,
)


def input_specs(shape: ShapeConfig, cfg: ModelConfig = FULL) -> dict:
    return lm_input_specs(cfg, shape)


register("granite-34b", sys.modules[__name__])
