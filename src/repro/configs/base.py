"""Config dataclasses + the architecture registry.

``ModelConfig`` is the single source of truth a model family reads; each
assigned architecture file (``src/repro/configs/<id>.py``) exports

  FULL   : the exact published configuration (dry-run / roofline only)
  SMOKE  : a reduced same-family configuration (CPU smoke tests)
  input_specs(shape) : jax.ShapeDtypeStruct stand-ins for every model input

Shapes are the four assigned input regimes; ``long_500k`` cells that are
architecturally infeasible (pure full attention) are marked ``supported=False``
and justified in DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | multimodal
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab: int = 0
    activation: str = "silu"
    rope_theta: float = 10000.0
    sliding_window: int | None = None
    layer_pattern: str = "global"  # global | local | alternating(local,global)
    attn_softcap: float | None = None
    final_softcap: float | None = None
    query_scale: float | None = None
    post_norms: bool = False
    tie_embeddings: bool = True
    norm: str = "rmsnorm"
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "sparse"  # sparse (per-seq dispatch) | dense (GSPMD)
    # SSM (mamba-2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    d_inner: int = 0
    conv_kernel: int = 4
    ssd_chunk: int = 256
    # audio (musicgen): parallel codebook streams
    n_codebooks: int = 0
    # vlm (llava): number of image patch embeddings prepended (frontend stub)
    n_patches: int = 0
    # LoRA (RELIEF operates on these)
    lora_rank: int = 8
    lora_alpha: float = 16.0
    lora_targets: tuple[str, ...] = ("wq", "wv", "wo_fusion")
    # numerics / execution
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    lora_dtype: str = "float32"
    q_chunk: int = 1024
    attn_impl: str = "xla"  # xla | pallas
    # scan over layers (O(1) HLO, fast compile) vs unrolled (exact
    # cost_analysis — XLA counts while bodies once; the dry-run unrolls)
    scan_layers: bool = True
    remat: str = "dots"  # none | dots | full
    # sequence parallelism (Megatron-SP): residual stream sharded over the
    # `model` axis between TP regions (all-reduce -> reduce-scatter +
    # all-gather; saved activations shrink by the TP degree)
    seq_shard: bool = False
    # CE loss computed in S-chunks (bounds the [B,S,V] logits transient for
    # 100k-256k vocabs); 1 = off
    loss_chunks: int = 1
    fsdp: bool = False  # shard base params over the data axis in training
    quantize_serve: bool = False  # int8 base weights on the serve path
    kv_quant: bool = False  # int8 KV cache with per-token scales (serving)

    @property
    def heads_per_group(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def runtime_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def p_dtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}

_REGISTRY: dict[str, Any] = {}


def register(arch_id: str, module) -> None:
    _REGISTRY[arch_id] = module


def get_arch(arch_id: str):
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    import importlib

    for name in (
        "phi3_medium_14b", "gemma2_27b", "granite_34b", "granite_3_8b",
        "llava_next_34b", "musicgen_large", "mixtral_8x7b", "mixtral_8x22b",
        "mamba2_1_3b", "hymba_1_5b", "relief_har",
    ):
        importlib.import_module(f"repro.configs.{name}")


# ---------------------------------------------------------------------------
# shared input_specs helpers
# ---------------------------------------------------------------------------


def lm_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for an LM step (no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against a cache of S tokens
    return {"token": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


def supports(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k requires sub-quadratic attention (bounded KV or SSM state)."""
    if shape.name != "long_500k":
        return True
    if cfg.family in ("ssm", "hybrid"):
        return True
    # sliding-window (rolling KV) or alternating local/global qualify
    return cfg.sliding_window is not None
