"""The paper's own task configurations: PAMAP2 / MHEALTH multimodal HAR
with Backbone 1 (CNN, full-parameter) and Backbone 2 (frozen transformer +
LoRA rho=8). These drive the reproduction benchmarks, not the dry-run grid."""
import sys

from repro.data.har import mm_config_for

# paper-scale configs (Sec. VI-A3)
PAMAP2_B1 = mm_config_for("pamap2", backbone="cnn", d_feat=32,
                          d_fused=128, cnn_ch=(32, 64))
PAMAP2_B2 = mm_config_for("pamap2", backbone="transformer", d_feat=32,
                          d_fused=128, enc_layers=4, enc_d=128, enc_ff=256)
MHEALTH_B1 = mm_config_for("mhealth", backbone="cnn", d_feat=32,
                           d_fused=128, cnn_ch=(32, 64))
MHEALTH_B2 = mm_config_for("mhealth", backbone="transformer", d_feat=32,
                           d_fused=128, enc_layers=4, enc_d=128, enc_ff=256)

# reduced configs for CPU benchmarks/tests
PAMAP2_B1_SMALL = mm_config_for("pamap2", backbone="cnn", d_feat=16,
                                d_fused=64, cnn_ch=(16, 32))
PAMAP2_B2_SMALL = mm_config_for("pamap2", backbone="transformer", d_feat=16,
                                d_fused=64, enc_layers=2, enc_d=32, enc_ff=64)
MHEALTH_B1_SMALL = mm_config_for("mhealth", backbone="cnn", d_feat=16,
                                 d_fused=64, cnn_ch=(16, 32))
MHEALTH_B2_SMALL = mm_config_for("mhealth", backbone="transformer",
                                 d_feat=16, d_fused=64, enc_layers=2,
                                 enc_d=32, enc_ff=64)

CONFIGS = {
    ("pamap2", "b1"): PAMAP2_B1, ("pamap2", "b2"): PAMAP2_B2,
    ("mhealth", "b1"): MHEALTH_B1, ("mhealth", "b2"): MHEALTH_B2,
    ("pamap2", "b1", "small"): PAMAP2_B1_SMALL,
    ("pamap2", "b2", "small"): PAMAP2_B2_SMALL,
    ("mhealth", "b1", "small"): MHEALTH_B1_SMALL,
    ("mhealth", "b2", "small"): MHEALTH_B2_SMALL,
}
