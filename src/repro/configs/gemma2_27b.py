"""gemma2-27b [arXiv:2408.00118]: 46L, d=4608, 32H (GQA kv=16), d_ff=36864,
vocab=256000 — alternating local(4096)/global attention, logit softcaps,
post-norms, GeGLU, query_pre_attn_scalar."""
from repro.configs.base import (ModelConfig, ShapeConfig, lm_input_specs,
                                register)
import sys

FULL = ModelConfig(
    arch="gemma2-27b", family="dense", n_layers=46, d_model=4608, n_heads=32,
    n_kv_heads=16, head_dim=128, d_ff=36864, vocab=256000, activation="gelu",
    layer_pattern="alternating", sliding_window=4096, attn_softcap=50.0,
    final_softcap=30.0, post_norms=True, tie_embeddings=True,
    # gemma2-27b: query_pre_attn_scalar = d_model/n_heads = 144; logits are
    # scaled by 1/sqrt(144) instead of the default 1/sqrt(head_dim=128)
    query_scale=144.0 ** -0.5,
    dtype="bfloat16", param_dtype="bfloat16", q_chunk=1024, remat="dots",
)

SMOKE = ModelConfig(
    arch="gemma2-27b-smoke", family="dense", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=192, vocab=128,
    activation="gelu", layer_pattern="alternating", sliding_window=16,
    attn_softcap=50.0, final_softcap=30.0, post_norms=True,
    query_scale=1.0 / 4.0, dtype="float32", param_dtype="float32",
    remat="none", q_chunk=32,
)


def input_specs(shape: ShapeConfig, cfg: ModelConfig = FULL) -> dict:
    return lm_input_specs(cfg, shape)


register("gemma2-27b", sys.modules[__name__])
