"""hymba-1.5b [arXiv:2411.13676]: 32L, d=1600, 25H (GQA kv=5, head 64)
parallel with SSD heads (d_inner=3200, state 16), d_ff=5504, vocab=32001.
Sliding-window attention throughout (the published model keeps 3 global
layers; we use all-SWA — noted in DESIGN.md §4 — which is what makes
long_500k feasible). The [attn_out ; ssm_out] fusion projection is the
closest assigned analogue of the paper's modality-blocked fusion layer:
MDLoRA block 0 = attention heads, block 1 = SSM heads."""
import sys

from repro.configs.base import (ModelConfig, ShapeConfig, lm_input_specs,
                                register)

FULL = ModelConfig(
    arch="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, head_dim=64, d_ff=5504, vocab=32001,
    ssm_state=16, ssm_head_dim=64, d_inner=3200, conv_kernel=4,
    ssd_chunk=64, layer_pattern="local", sliding_window=1024,
    activation="silu", tie_embeddings=True, dtype="bfloat16",
    param_dtype="bfloat16", q_chunk=1024, remat="dots",
    lora_targets=("wq", "wv", "wo_fusion"),
)

SMOKE = ModelConfig(
    arch="hymba-1.5b-smoke", family="hybrid", n_layers=2, d_model=64,
    n_heads=5, n_kv_heads=1, head_dim=16, d_ff=128, vocab=97, ssm_state=8,
    ssm_head_dim=16, d_inner=64, conv_kernel=4, ssd_chunk=16,
    layer_pattern="local", sliding_window=16, dtype="float32",
    param_dtype="float32", remat="none", q_chunk=16,
)


def input_specs(shape: ShapeConfig, cfg: ModelConfig = FULL) -> dict:
    return lm_input_specs(cfg, shape)


register("hymba-1.5b", sys.modules[__name__])
