"""llava-next-34b [hf:llava-hf/llava-v1.6-34b-hf backbone class]: 60L,
d=7168, 56H (GQA kv=8), d_ff=20480, vocab=64000 — VLM. The anyres-tiling
vision frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed patch embeddings [B, n_patches, d_model] that the backbone
prepends to the token stream. The patch/text boundary is the natural MDLoRA
modality block (DESIGN.md §4)."""
import sys

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, ShapeConfig, lm_input_specs,
                                register)

N_PATCHES = 2880  # anyres 4+1 tiles x 576 patches

FULL = ModelConfig(
    arch="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, head_dim=128, d_ff=20480, vocab=64000,
    activation="silu", rope_theta=5000000.0, tie_embeddings=False,
    n_patches=N_PATCHES, dtype="bfloat16", param_dtype="bfloat16",
    q_chunk=1024, remat="dots",
)

SMOKE = ModelConfig(
    arch="llava-next-34b-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=97,
    tie_embeddings=False, n_patches=16, dtype="float32",
    param_dtype="float32", remat="none", q_chunk=16,
)


def input_specs(shape: ShapeConfig, cfg: ModelConfig = FULL) -> dict:
    """Prefill/train sequences = [patch embeddings ; text tokens], totalling
    shape.seq_len positions; decode runs on the text tail."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return lm_input_specs(cfg, shape)
    n_text = S - cfg.n_patches
    assert n_text > 0, (S, cfg.n_patches)
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, n_text), jnp.int32),
        "patches": jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model),
                                        jnp.bfloat16 if cfg.dtype ==
                                        "bfloat16" else jnp.float32),
    }
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, n_text), jnp.int32)
    return specs


register("llava-next-34b", sys.modules[__name__])
