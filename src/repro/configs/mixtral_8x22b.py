"""mixtral-8x22b [arXiv:2401.04088 family]: 56L, d=6144, 48H (GQA kv=8),
expert d_ff=16384, vocab=32768, MoE 8 experts top-2, SWA. ~141B params —
the largest assigned arch: training shards parameters over data as well
(fsdp=True) and serving uses int8 base weights (quantize_serve)."""
import sys

from repro.configs.base import (ModelConfig, ShapeConfig, lm_input_specs,
                                register)

FULL = ModelConfig(
    arch="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384, vocab=32768,
    n_experts=8, top_k=2, capacity_factor=1.25, activation="silu",
    layer_pattern="local", sliding_window=4096, tie_embeddings=False,
    fsdp=True, quantize_serve=True, dtype="bfloat16", param_dtype="bfloat16",
    q_chunk=1024, remat="dots",
)

SMOKE = ModelConfig(
    arch="mixtral-8x22b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96, vocab=101, n_experts=4,
    top_k=2, layer_pattern="local", sliding_window=16, tie_embeddings=False,
    dtype="float32", param_dtype="float32", remat="none", q_chunk=32,
)


def input_specs(shape: ShapeConfig, cfg: ModelConfig = FULL) -> dict:
    return lm_input_specs(cfg, shape)


register("mixtral-8x22b", sys.modules[__name__])
