"""mixtral-8x7b [arXiv:2401.04088]: 32L, d=4096, 32H (GQA kv=8), expert
d_ff=14336, vocab=32000, MoE 8 experts top-2, sliding-window attention."""
import sys

from repro.configs.base import (ModelConfig, ShapeConfig, lm_input_specs,
                                register)

FULL = ModelConfig(
    arch="mixtral-8x7b", family="moe", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32000, n_experts=8,
    top_k=2, capacity_factor=1.25, activation="silu", layer_pattern="local",
    sliding_window=4096, tie_embeddings=False, dtype="bfloat16",
    param_dtype="bfloat16", q_chunk=1024, remat="dots",
)

SMOKE = ModelConfig(
    arch="mixtral-8x7b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96, vocab=101, n_experts=4,
    top_k=2, layer_pattern="local", sliding_window=16, tie_embeddings=False,
    dtype="float32", param_dtype="float32", remat="none", q_chunk=32,
)


def input_specs(shape: ShapeConfig, cfg: ModelConfig = FULL) -> dict:
    return lm_input_specs(cfg, shape)


register("mixtral-8x7b", sys.modules[__name__])
