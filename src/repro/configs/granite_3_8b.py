"""granite-3-8b [hf:ibm-granite/granite-3.0-*-base family]: 40L, d=4096,
32H (GQA kv=8), d_ff=12800, vocab=49155 — GQA + SwiGLU."""
from repro.configs.base import (ModelConfig, ShapeConfig, lm_input_specs,
                                register)
import sys

FULL = ModelConfig(
    arch="granite-3-8b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=12800, vocab=49155,
    activation="silu", tie_embeddings=True, dtype="bfloat16",
    param_dtype="bfloat16", q_chunk=1024, remat="dots",
)

SMOKE = ModelConfig(
    arch="granite-3-8b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=100, vocab=99,
    dtype="float32", param_dtype="float32", remat="none", q_chunk=32,
)


def input_specs(shape: ShapeConfig, cfg: ModelConfig = FULL) -> dict:
    return lm_input_specs(cfg, shape)


register("granite-3-8b", sys.modules[__name__])
