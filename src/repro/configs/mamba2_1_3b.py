"""mamba2-1.3b [arXiv:2405.21060]: 48L, d=2048, attention-free SSD,
ssm_state=128, d_inner=4096, head_dim=64 (64 SSD heads), vocab=50280.
DESIGN.md §4: MDLoRA's *modality* semantics do not apply (attention-free,
single stream); the parameter-GROUP interface (per-layer mixer groups) is
what RELIEF's allocation/aggregation operate on."""
import sys

from repro.configs.base import (ModelConfig, ShapeConfig, lm_input_specs,
                                register)

FULL = ModelConfig(
    arch="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048, vocab=50280,
    ssm_state=128, ssm_head_dim=64, d_inner=4096, conv_kernel=4,
    ssd_chunk=64, tie_embeddings=True, dtype="bfloat16",
    param_dtype="bfloat16", remat="dots",
)

SMOKE = ModelConfig(
    arch="mamba2-1.3b-smoke", family="ssm", n_layers=2, d_model=64, vocab=96,
    ssm_state=16, ssm_head_dim=16, d_inner=128, conv_kernel=4, ssd_chunk=16,
    dtype="float32", param_dtype="float32", remat="none",
)


def input_specs(shape: ShapeConfig, cfg: ModelConfig = FULL) -> dict:
    return lm_input_specs(cfg, shape)


register("mamba2-1.3b", sys.modules[__name__])
