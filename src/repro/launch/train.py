"""Training launcher — runs the real training loop on whatever devices exist.

Two modes:
  backbone   LoRA fine-tune (or full-param train) one of the assigned
             architectures on synthetic token streams, sharded over the
             host mesh, with checkpoint/restart.
  federated  the paper's RELIEF protocol on synthetic PAMAP2/MHEALTH
             (delegates to repro.core.engine; see examples/ for drivers).

Usage:
  python -m repro.launch.train --arch phi3-medium-14b --smoke --steps 20
  python -m repro.launch.train --mode federated --dataset pamap2 \
      --backbone cnn --strategy relief --rounds 40
"""
from __future__ import annotations

import argparse
import os
import time


def train_backbone(args):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import CheckpointManager
    from repro.configs import base
    from repro.data.tokens import synthetic_token_batches
    from repro.dist import sharding as SH
    from repro.launch import step_fns as SF
    from repro.launch.mesh import make_host_mesh
    from repro.optim import adam_init

    mod = base.get_arch(args.arch)
    cfg = mod.SMOKE if args.smoke else mod.FULL
    mesh = make_host_mesh(args.model_parallel)
    key = jax.random.PRNGKey(args.seed)

    from repro.models import api
    params = api.init_model(key, cfg)
    tr, _ = SF.split_trainable(params, args.train_mode)
    opt = adam_init(tr)
    step_fn = SF.make_train_step(cfg, lr=args.lr, train_mode=args.train_mode)

    pspec = SH.param_specs(cfg, params, mesh)
    shard = lambda t: SH.to_named(mesh, t)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    start_step = 0
    restored = ckpt.restore_latest({"params": params, "opt": opt})
    if restored is not None:
        (state, meta) = restored
        params, opt = state["params"], state["opt"]
        start_step = meta["step"]
        print(f"[train] resumed from step {start_step}")

    jit_step = jax.jit(step_fn)
    batches = synthetic_token_batches(cfg.vocab, args.batch, args.seq,
                                      args.steps, seed=args.seed,
                                      n_codebooks=cfg.n_codebooks)
    t0 = time.time()
    with mesh:
        for i, batch in enumerate(batches):
            step = start_step + i
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if cfg.family == "vlm":
                batch["patches"] = jnp.zeros(
                    (args.batch, cfg.n_patches, cfg.d_model),
                    cfg.runtime_dtype())
            params, opt, metrics = jit_step(params, opt, batch)
            if (step + 1) % args.log_every == 0:
                print(f"[train] step {step+1} loss "
                      f"{float(metrics['loss']):.4f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)")
            if (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt},
                          {"arch": args.arch})
    final = float(metrics["loss"])
    print(f"[train] done at step {start_step + args.steps}, loss {final:.4f}")
    return final


def train_federated(args):
    import jax

    from repro.core.engine import FedConfig, FedRun
    from repro.core.strategies import get_strategy
    from repro.core.tasks import MMTask
    from repro.data import make_har_dataset, mm_config_for
    from repro.sim import make_fleet

    ds = make_har_dataset(args.dataset, windows_per_subject=args.windows,
                          seed=args.seed)
    n_low = 2 if args.dataset == "pamap2" else 4
    fleet = make_fleet(3, 3, n_low, M=4)
    cfg = mm_config_for(args.dataset, backbone={"cnn": "cnn", "b1": "cnn",
                                                "b2": "transformer"}.get(
        args.backbone, args.backbone))
    task, tr0 = MMTask.create(cfg, jax.random.PRNGKey(args.seed))
    fed = FedConfig(rounds=args.rounds, eval_every=args.eval_every,
                    seed=args.seed, utilization=2e-5)
    run = FedRun.create(task, tr0, get_strategy(args.strategy), fleet, fed)
    run.run(ds, log_every=args.eval_every)
    print(f"[federated] {args.strategy} final F1 {run.history['f1'][-1]:.4f}")
    return run.history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="backbone",
                    choices=["backbone", "federated"])
    ap.add_argument("--arch", default="phi3-medium-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--train-mode", default="lora", choices=["lora", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    # federated
    ap.add_argument("--dataset", default="pamap2")
    ap.add_argument("--backbone", default="cnn")
    ap.add_argument("--strategy", default="relief")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--windows", type=int, default=160)
    args = ap.parse_args()
    if args.mode == "backbone":
        train_backbone(args)
    else:
        train_federated(args)


if __name__ == "__main__":
    main()
