"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
extract memory/cost/collective evidence for EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch phi3-medium-14b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod] \
      [--out-dir benchmarks/results/dryrun]

Each cell writes <out-dir>/<arch>__<shape>__<mesh>.json; existing files are
skipped (the full grid is resumable after interruption — the same mechanism
a real cluster launcher uses for preemption tolerance).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every jax import: jax locks the device count on first init.
# The 512 host devices exist ONLY for this dry-run (16x16 single-pod and
# 2x16x16 multi-pod production meshes); tests and benches see 1 device.

import argparse
import dataclasses
import json
import sys
import time
import traceback


def _compile_step(cfg, mod, shape, mesh, train_mode):
    """Lower + compile one step function on ``mesh``; returns the compiled
    artifact. Buffers are donated (params/opt for train, caches for decode)
    so memory_analysis reflects in-place updates."""
    import jax

    from repro.dist import sharding as SH
    from repro.launch import step_fns as SF

    params = SF.abstract_params(cfg)
    strategy = SH.pick_strategy(cfg, shape.kind)
    n_devices = mesh.devices.size
    if (strategy == "fsdp" and shape.global_batch % n_devices != 0):
        # multi-pod: global_batch (256) < chips (512) — pure FSDP leaves the
        # model axis without a batch dim; hybrid TP(model) x DP(pod,data)
        # keeps every chip busy (EXPERIMENTS.md §Dry-run note)
        strategy = "tp"
    if strategy in ("fsdp", "replicated"):
        batch_axes = SH.data_axes(mesh) + (("model",) if "model" in
                                           mesh.axis_names else ())
    else:
        batch_axes = SH.data_axes(mesh)
    SH.set_activation_mesh(mesh, batch_axes=batch_axes,
                           tp=(strategy == "tp"))
    pspec = SH.param_specs(cfg, params, mesh, train=(shape.kind == "train"),
                           strategy=strategy)
    shard = lambda t: SH.to_named(mesh, t)
    with mesh:
        if shape.kind == "train":
            tr, _ = SF.split_trainable(params, train_mode)
            opt = SF.abstract_opt_state(tr)
            # trainable specs = matching SUBTREE of the full param specs
            pspec_tr = pspec["lora"] if train_mode == "lora" else pspec
            ospec = SH.opt_state_specs(pspec_tr, opt, mesh)
            batch = mod.input_specs(shape, cfg)
            bspec = SH.batch_specs(batch, mesh, cfg, strategy)
            fn = SF.make_train_step(cfg, train_mode=train_mode)
            lowered = jax.jit(fn, in_shardings=(
                shard(pspec), shard(ospec), shard(bspec)),
                donate_argnums=(0, 1)).lower(params, opt, batch)
        elif shape.kind == "prefill":
            batch = mod.input_specs(shape, cfg)
            bspec = SH.batch_specs(batch, mesh, cfg)
            fn = SF.make_prefill_step(cfg)
            lowered = jax.jit(fn, in_shardings=(
                shard(pspec), shard(bspec))).lower(params, batch)
        else:  # decode
            specs_in = mod.input_specs(shape, cfg)
            caches = SF.abstract_caches(cfg, shape.global_batch,
                                        shape.seq_len)
            cspec = SH.cache_specs(cfg, caches, mesh)
            tok_spec = SH.batch_specs(specs_in["token"], mesh, cfg)
            fn = SF.make_serve_step(cfg)
            lowered = jax.jit(fn, in_shardings=(
                shard(pspec), shard(cspec), shard(tok_spec), None),
                donate_argnums=(1,)).lower(params, caches,
                                           specs_in["token"],
                                           specs_in["pos"])
        return lowered.compile()


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             train_mode: str = "lora", hillclimb: dict | None = None,
             probes: tuple = (), full_scan: bool = True) -> dict:
    """One dry-run cell.

    Two compilations per cell:
      1. FULL depth, scan-over-layers  -> proves the production graph
         compiles on the mesh + exact peak-memory analysis (the bwd
         activation stack appears in the scanned graph's buffers).
      2. Unrolled depth-L probes (L = n_sub, 2*n_sub) -> exact per-layer
         FLOPs/bytes/collective bytes (XLA cost_analysis counts while-loop
         bodies ONCE - measured; see roofline.py), extrapolated linearly:
         metric(L) = const + per_layer * L.
    """
    import jax

    from repro.configs import base
    from repro.dist import sharding as SH
    from repro.launch import roofline as RL
    from repro.launch.mesh import make_production_mesh

    mod = base.get_arch(arch)
    cfg0 = mod.FULL
    shape = base.SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    n_chips = 512 if multi_pod else 256

    if not base.supports(cfg0, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped",
                "reason": "long_500k requires sub-quadratic attention "
                          "(pure full-attention arch; DESIGN.md par.4)"}

    tweaks: dict = {"q_chunk": 256}
    if cfg0.family == "moe":
        tweaks |= {"moe_impl": "dense"}  # §Perf Cell B: sparse dispatch is
        # GSPMD-pathological at mesh scale; dense mixture is the baseline
    if shape.kind == "train":
        # bf16 LoRA compute on TPU (fp32 Adam moments regardless): fp32
        # adapters promoted whole activation tensors to f32 around every
        # LoRA matmul, doubling AG/AR bytes (§Perf phi3 iteration 2)
        tweaks |= {"remat": "full", "seq_shard": True, "loss_chunks": 8,
                   "lora_dtype": "bfloat16"}
    if hillclimb:
        tweaks |= hillclimb
    cfg = dataclasses.replace(cfg0, **tweaks)

    mesh = make_production_mesh(multi_pod=multi_pod)
    SH.set_activation_mesh(mesh)

    from repro.models.transformer import pattern
    n_sub = pattern(cfg)[0] if cfg.family in ("dense", "moe", "vlm",
                                              "audio") else 1

    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "status": "ok", "n_chips": n_chips,
              "train_mode": train_mode if shape.kind == "train" else None,
              "config_tweaks": tweaks, "hillclimb": hillclimb or {}}

    # --- 1. full-depth scanned compile: shardability + memory ---------------
    t0 = time.time()
    if full_scan:
        full_cfg = dataclasses.replace(cfg, scan_layers=True)
        compiled = _compile_step(full_cfg, mod, shape, mesh, train_mode)
        ma = compiled.memory_analysis()
        raw = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
               + ma.output_size_in_bytes - ma.alias_size_in_bytes)
        # The CPU backend does not implement buffer donation, so donated
        # outputs (params/opt for train, caches for decode) are double
        # counted; on TPU they alias their inputs. Report both.
        donated = (ma.output_size_in_bytes if shape.kind in ("train",
                                                             "decode")
                   else 0)
        adj = raw - donated
        result["compile_s"] = round(time.time() - t0, 1)
        result["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "donation_adjusted_bytes": adj,
            "per_device_gb_raw": round(raw / 2**30, 3),
            "per_device_gb": round(adj / 2**30, 3),
            "fits_16gb_hbm": adj < 16 * 2**30,
        }
        del compiled

    # --- 2. depth probes: exact per-layer roofline terms --------------------
    if probes == "skip":  # multi-pod pass: compile+memory proof only
        return result
    probes = probes or (n_sub, 2 * n_sub)
    probe_stats = []
    for L in probes:
        pcfg = dataclasses.replace(cfg, n_layers=L, scan_layers=False)
        t1 = time.time()
        compiled = _compile_step(pcfg, mod, shape, mesh, train_mode)
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # jaxlib<0.4.38 returns per-device
            ca = ca[0] if ca else {}
        hlo = compiled.as_text()
        coll = RL.parse_collectives(hlo)
        probe_stats.append({
            "layers": L,
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll_bytes": float(coll.bytes_entry + coll.bytes_scanned),
            "coll_counts": coll.counts,
            "compile_s": round(time.time() - t1, 1),
        })
        del compiled, hlo

    (p1, p2) = probe_stats[-2:]
    L_full = cfg.n_layers

    def extrap(key):
        per_layer = (p2[key] - p1[key]) / max(p2["layers"] - p1["layers"], 1)
        const = p1[key] - per_layer * p1["layers"]
        return max(const + per_layer * L_full, 0.0), per_layer

    flops, flops_pl = extrap("flops")
    byts, bytes_pl = extrap("bytes")
    cbytes, cbytes_pl = extrap("coll_bytes")
    terms = {
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": byts,
        "collective_bytes_per_device": cbytes,
        "per_layer": {"flops": flops_pl, "bytes": bytes_pl,
                      "coll_bytes": cbytes_pl},
        "collective_counts_probe": p2["coll_counts"],
        "t_compute_s": flops / RL.PEAK_FLOPS,
        "t_memory_s": byts / RL.HBM_BW,
        "t_collective_s": cbytes / RL.LINK_BW,
    }
    terms["dominant"] = max(
        (("compute", terms["t_compute_s"]), ("memory", terms["t_memory_s"]),
         ("collective", terms["t_collective_s"])), key=lambda kv: kv[1])[0]
    mf = RL.model_flops(cfg0, shape, train_mode)
    result["probes"] = probe_stats
    result["roofline"] = terms
    result["model_flops"] = mf
    result["useful_flops_ratio"] = (mf["model_flops"] / n_chips
                                    / max(flops, 1.0))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--train-mode", default="lora", choices=["lora", "full"])
    ap.add_argument("--out-dir", default="benchmarks/results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--hillclimb-json", default=None,
                    help="JSON dict of ModelConfig overrides (perf iteration)")
    ap.add_argument("--skip-probes", action="store_true",
                    help="compile+memory only (multi-pod shardability pass)")
    args = ap.parse_args()

    from repro.configs import base

    archs = base.list_archs() if args.arch == "all" else [args.arch]
    shapes = list(base.SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    hc = json.loads(args.hillclimb_json) if args.hillclimb_json else None

    os.makedirs(args.out_dir, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if hc:
                    tag += "__hc" + "-".join(f"{k}={v}" for k, v in
                                             sorted(hc.items()))
                out = os.path.join(args.out_dir, tag + ".json")
                if os.path.exists(out) and not args.force:
                    print(f"[skip cached] {tag}")
                    continue
                print(f"[run] {tag} ...", flush=True)
                try:
                    res = run_cell(arch, shape, mp, args.train_mode, hc,
                                   probes="skip" if args.skip_probes else ())
                except Exception as e:  # noqa: BLE001 — record and continue
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                with open(out, "w") as f:
                    json.dump(res, f, indent=1)
                msg = res["status"]
                if res["status"] == "ok" and "roofline" not in res:
                    msg += (f" compile={res.get('compile_s')}s "
                            f"mem={res.get('memory', {}).get('per_device_gb')}GB")
                elif res["status"] == "ok":
                    r = res["roofline"]
                    msg += (f" compile={res['compile_s']}s "
                            f"mem={res['memory']['per_device_gb']}GB "
                            f"dom={r['dominant']} "
                            f"tc={r['t_compute_s']:.4f} "
                            f"tm={r['t_memory_s']:.4f} "
                            f"tx={r['t_collective_s']:.4f}")
                print(f"[done] {tag}: {msg}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
