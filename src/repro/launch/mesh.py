"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax import.

Mesh shapes (TPU v5e):
  single-pod : (16, 16)    axes (data, model)        = 256 chips
  multi-pod  : (2, 16, 16) axes (pod, data, model)   = 512 chips

``data`` doubles as the FL client axis (DESIGN.md §3); ``pod`` is the
cross-pod (DCN) data/client axis — hierarchical aggregation reduces within
pods over ICI first, then across pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over the actually-available devices (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
