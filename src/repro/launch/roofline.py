"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS        (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_device / HBM_BW            (819 GB/s)
  collective = collective_bytes_per_device / LINK_BW    (~50 GB/s/link ICI)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are NOT
in cost_analysis: we parse the optimized HLO, summing result sizes of every
all-gather / all-reduce (x2: reduce+broadcast phases) / reduce-scatter /
all-to-all / collective-permute. Collectives inside the layer-scan while
loop appear once in the HLO text but execute once per scan step, so ops
found outside the ENTRY computation are multiplied by the scan trip count
(models here have exactly one depth-scan; documented limitation).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s/link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    """Sum byte sizes of all array shapes in an HLO type string
    (handles tuples like ``(f32[8,128], f32[8,128])``)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_entry: int
    bytes_scanned: int  # inside while bodies (per trip)
    counts: dict

    def total(self, scan_steps: int) -> int:
        return self.bytes_entry + self.bytes_scanned * max(scan_steps, 1)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    bytes_entry = 0
    bytes_scanned = 0
    counts: dict[str, int] = {}
    in_entry = False
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("ENTRY "):
            in_entry = True
            continue
        if in_entry and stripped == "}":
            in_entry = False
            continue
        m = re.search(r"=\s*([^=]+?)\s+(" + "|".join(_COLLECTIVES)
                      + r")(?:-start|-done)?\(", stripped)
        if not m:
            continue
        op = m.group(2)
        if "-done(" in stripped:
            continue  # avoid double counting async start/done pairs
        b = _type_bytes(m.group(1))
        if op == "all-reduce":
            b *= 2  # reduce + broadcast phases on a ring
        counts[op] = counts.get(op, 0) + 1
        if in_entry:
            bytes_entry += b
        else:
            bytes_scanned += b
    return CollectiveStats(bytes_entry, bytes_scanned, counts)


def roofline_terms(cost: dict, hlo_text: str, scan_steps: int) -> dict:
    """cost: compiled.cost_analysis() dict (per-device program)."""
    coll = parse_collectives(hlo_text)
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    cbytes = float(coll.total(scan_steps))
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_collective = cbytes / LINK_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_collective)), key=lambda kv: kv[1])[0]
    return {
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": cbytes,
        "collective_counts": coll.counts,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
    }


def mdlora_block_plan(shapes, impl: str = "pallas",
                      interpret: bool | None = None) -> list[dict]:
    """Autotuned block plan + roofline terms for the mdlora kernels.

    shapes: iterable of dicts {"T", "D", "F", "r"} and optionally
    {"multi": bool, "n_adapters": int} — e.g. the serving engine's decode
    batch (T = batch rows, multi = gathered adapter store). For each shape,
    resolves (bt, bf, bd) through the shared timed-sweep autotuner
    (kernels/cohort_agg/autotune.py; largest-divisor heuristic in interpret
    mode) and reports the kernel's compute/memory roofline terms so the
    serving bench can tell which side of the ridge a cell sits on.
    """
    from repro.kernels.cohort_agg.autotune import select_mdlora_blocks
    from repro.kernels.runtime import resolve_interpret

    interpret = resolve_interpret(interpret)
    out = []
    for s in shapes:
        T, D, F, r = int(s["T"]), int(s["D"]), int(s["F"]), int(s["r"])
        multi = bool(s.get("multi", False))
        A = int(s.get("n_adapters", 1))
        bt, bf, bd = select_mdlora_blocks((T, D, F, r), impl=impl,
                                          interpret=interpret, multi=multi,
                                          n_adapters=A)
        flops = 2.0 * T * D * (F + r) + 2.0 * T * r * F
        # streamed bytes: x + w0 once per F-tile sweep, adapter tiles per
        # row (multi) or once (single), output once; fp32 accumulators
        adapter_rows = T if multi else 1
        bytes_accessed = 4.0 * (T * D + D * F * (1 if T <= bt else T // bt)
                                + adapter_rows * (D * r + r * F) + T * F)
        t_c, t_m = flops / PEAK_FLOPS, bytes_accessed / HBM_BW
        out.append({
            "T": T, "D": D, "F": F, "r": r, "multi": multi,
            "n_adapters": A, "bt": bt, "bf": bf, "bd": bd,
            "flops": flops, "bytes": bytes_accessed,
            "intensity": flops / max(bytes_accessed, 1.0),
            "t_compute_s": t_c, "t_memory_s": t_m,
            "dominant": "compute" if t_c >= t_m else "memory",
        })
    return out


def model_flops(cfg, shape, train_mode: str = "lora") -> dict:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE), with the
    LoRA-adjusted ideal (~4*N*D: frozen weights skip dW) reported alongside."""
    from repro.models import api as mapi
    import jax

    params = jax.eval_shape(
        lambda k: mapi.init_model(k, cfg), jax.random.PRNGKey(0))
    n_total = sum(x.size for x in jax.tree.leaves(params["base"]))
    if cfg.n_experts:
        # active = non-expert params + top_k/n_experts of expert params
        expert = sum(
            x.size for p, x in
            jax.tree_util.tree_flatten_with_path(params["base"])[0]
            if re.search(r"\['(wi|wg|wo)'\]", jax.tree_util.keystr(p))
            and x.ndim == 4)
        n_active = n_total - expert + expert * cfg.top_k / cfg.n_experts
    else:
        n_active = n_total
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    factor = {"train": 6.0 if train_mode == "full" else 4.0,
              "prefill": 2.0, "decode": 2.0}[shape.kind]
    return {
        "n_params": n_total, "n_active": n_active, "tokens": tokens,
        "model_flops": factor * n_active * tokens,
        "model_flops_6nd": 6.0 * n_active * tokens,
    }
