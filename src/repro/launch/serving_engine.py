"""Batched multi-LoRA personalized serving engine.

RELIEF personalizes one modality-block LoRA adapter per client; at traffic
each request therefore carries its *own* adapter + modality mask. Serving
them one model at a time wastes the accelerator: every request re-runs the
full base model at batch 1. This engine instead:

* keeps client adapters in an ``AdapterRegistry`` — one [L, A, din, r]
  stacked store per LoRA target, ingesting per-client blocks straight from
  trainer output / ``CohortAggBuffer`` aggregates (no per-request weight
  copies, no merge step);
* runs **continuous batching**: requests join and leave the decode batch at
  step granularity. Admission prefalls the prompt into a fresh
  single-request cache and scatters that row into the shared paged
  KV/SSM cache (``models/api.init_caches(per_row_pos=True)``), so a new
  request never perturbs the rows already mid-stream;
* decodes the whole mixed batch with ONE fused gathered projection per
  LoRA target (``kernels/mdlora.mdlora_matmul_multi``): per-row
  ``adapter_idx`` gathers each request's adapter blocks inside the kernel
  and per-row fusion masks zero absent-modality blocks.

``naive_serve`` is the baseline the bench compares against: sequential
per-request decode with merged single-adapter params.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api

Array = jax.Array


# jitted step functions are cached at module level (ModelConfig is a frozen
# hashable dataclass) so constructing a new engine or re-running the naive
# baseline reuses compiled code instead of retracing per instance; each
# returns greedy token ids directly so a serving step is ONE dispatch


@functools.lru_cache(maxsize=None)
def _decode_fn(cfg: ModelConfig, lora_impl: str):
    def f(base, store, fmasks, caches, token, pos, aidx):
        fmask = jnp.take(fmasks, aidx, axis=0)
        logits, caches = api.decode_step({"base": base, "lora": store}, cfg,
                                         caches, token, pos,
                                         adapter_idx=aidx, fusion_mask=fmask,
                                         lora_impl=lora_impl)
        return jnp.argmax(logits[:, 0], -1).astype(jnp.int32), caches
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _admit_fn(cfg: ModelConfig):
    """Admission as one fused call: gather the adapter from the store,
    prefill into a fresh single-row cache, scatter that row into the shared
    cache at ``slot`` and return the first greedy token."""
    def f(base, store, fmasks, fresh, big, tokens, aslot, slot):
        lora = jax.tree.map(lambda x: x[:, aslot], store)
        logits, small = api.prefill_with_cache(
            {"base": base, "lora": lora}, cfg, fresh, tokens,
            fusion_mask=fmasks[aslot][None])
        big = jax.tree.map(
            lambda b, o: b.at[:, slot].set(o[:, 0].astype(b.dtype)),
            big, small)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), big
    return jax.jit(f)  # jit's shape cache handles varying prompt lengths


@functools.lru_cache(maxsize=None)
def _single_prefill_fn(cfg: ModelConfig):
    def f(base, store, fmasks, fresh, tokens, aslot):
        lora = jax.tree.map(lambda x: x[:, aslot], store)
        logits, caches = api.prefill_with_cache(
            {"base": base, "lora": lora}, cfg, fresh, tokens,
            fusion_mask=fmasks[aslot][None])
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), caches
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _single_decode_fn(cfg: ModelConfig):
    def f(base, store, fmasks, caches, token, pos, aslot):
        lora = jax.tree.map(lambda x: x[:, aslot], store)
        logits, caches = api.decode_step(
            {"base": base, "lora": lora}, cfg, caches, token, pos,
            fusion_mask=fmasks[aslot][None])
        return jnp.argmax(logits[:, 0], -1).astype(jnp.int32), caches
    return jax.jit(f)


@dataclasses.dataclass
class Request:
    rid: str
    prompt: np.ndarray  # [P] int tokens
    adapter: str  # registry name
    max_new_tokens: int = 16
    submit_t: float = 0.0


# ---------------------------------------------------------------------------
# adapter registry
# ---------------------------------------------------------------------------


class AdapterRegistry:
    """Capacity-slotted store of per-client MDLoRA adapters.

    Leaves are stacked [L, capacity, din, r] so the model's layer-scan
    slicing ([L] leading axis) is untouched and the per-row gather happens
    inside the mdlora kernel. Registration writes one slot; eviction frees
    it. ``ingest_update`` applies a server-side delta (trainer /
    CohortAggBuffer.finalize output with the same [L, din, r] leaf layout)
    to a registered adapter in place — the serve path sees fresh weights on
    the next decode step without any repacking.
    """

    def __init__(self, key: Array, cfg: ModelConfig, capacity: int):
        self.cfg = cfg
        self.capacity = capacity
        proto = api.init_model(key, cfg)["lora"]
        # zeroed store: empty slots behave as base-model (b=0 -> delta 0)
        self.store = jax.tree.map(
            lambda x: jnp.zeros((x.shape[0], capacity) + x.shape[1:],
                                x.dtype), proto)
        self.block_dims = api.fusion_block_dims(cfg)
        df = int(sum(self.block_dims))
        self.fusion_masks = jnp.ones((capacity, df), jnp.float32)
        self._slots: dict[str, int] = {}
        self._free = list(range(capacity))

    def slot(self, name: str) -> int:
        return self._slots[name]

    def register(self, name: str, lora_tree: Any,
                 modality_mask=None) -> int:
        """lora_tree: [L, din, r]-leaf adapter (e.g. params["lora"]);
        modality_mask: [M] availability over ``api.fusion_block_dims``."""
        from repro.kernels.mdlora import block_row_mask

        if name in self._slots:
            s = self._slots[name]
        else:
            if not self._free:
                raise RuntimeError("adapter registry full")
            s = self._free.pop(0)
            self._slots[name] = s
        self.store = jax.tree.map(
            lambda big, leaf: big.at[:, s].set(leaf.astype(big.dtype)),
            self.store, lora_tree)
        mask = (jnp.ones((int(sum(self.block_dims)),), jnp.float32)
                if modality_mask is None
                else block_row_mask(self.block_dims, modality_mask))
        self.fusion_masks = self.fusion_masks.at[s].set(mask)
        return s

    def ingest_update(self, name: str, delta_tree: Any,
                      server_lr: float = 1.0) -> None:
        s = self._slots[name]
        self.store = jax.tree.map(
            lambda big, d: big.at[:, s].add(
                (server_lr * d).astype(big.dtype)),
            self.store, delta_tree)

    def evict(self, name: str) -> None:
        s = self._slots.pop(name)
        self.store = jax.tree.map(lambda big: big.at[:, s].set(0.0),
                                  self.store)
        self.fusion_masks = self.fusion_masks.at[s].set(1.0)
        self._free.append(s)

    def lora_view(self, name: str) -> Any:
        """Single-adapter [L, din, r] tree (naive baseline / admission)."""
        s = self._slots[name]
        return jax.tree.map(lambda big: big[:, s], self.store)


# ---------------------------------------------------------------------------
# continuous-batching engine
# ---------------------------------------------------------------------------


class ServingEngine:
    """Continuous-batching decode loop over ``batch_slots`` cache rows.

    Every step: (1) free slots are filled from the admission queue — the
    prompt is prefilled into a fresh single-row cache (chunked forward for
    attention archs, exact token loop for recurrent ones) and the row is
    scattered into the shared cache; (2) one jitted batched decode step
    advances all active rows, each applying its own adapter via the
    gathered mdlora kernel. Finished rows are recycled immediately.
    """

    def __init__(self, params: dict, cfg: ModelConfig,
                 registry: AdapterRegistry, batch_slots: int, max_len: int,
                 lora_impl: str = "xla"):
        self.cfg = cfg
        self.registry = registry
        self.B = batch_slots
        self.max_len = max_len
        self.params = {"base": params["base"]}
        self.caches = api.init_caches(cfg, batch_slots, max_len,
                                      per_row_pos=True)
        self.queue: list[Request] = []
        # per-slot host state
        self.active = np.zeros(batch_slots, bool)
        self.pos = np.zeros(batch_slots, np.int32)
        self.remaining = np.zeros(batch_slots, np.int32)
        self.adapter_idx = np.zeros(batch_slots, np.int32)
        self.rids: list[str | None] = [None] * batch_slots
        self.cur = np.zeros((batch_slots, 1), np.int32)
        self.outputs: dict[str, list[int]] = {}
        self.latency: dict[str, float] = {}
        self.step_times: list[float] = []
        self._submit_times: dict[str, float] = {}
        self._decode = _decode_fn(cfg, lora_impl)
        self._admit_step = _admit_fn(cfg)
        # immutable zeroed single-row cache reused by every admission
        self._fresh_row = api.init_caches(cfg, 1, max_len, per_row_pos=True)

    def submit(self, req: Request) -> None:
        req.submit_t = time.perf_counter()
        self._submit_times[req.rid] = req.submit_t
        self.queue.append(req)
        self.outputs[req.rid] = []

    # -- admission ---------------------------------------------------------

    def _admit(self, slot: int, req: Request) -> None:
        aslot = self.registry.slot(req.adapter)
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        # one fused dispatch: gather adapter, prefill the fresh row, scatter
        # it into the shared cache. The fresh row fully overwrites the slot
        # (pos=-1 beyond the prompt), so recycled slots carry no ghost KV
        # entries from the previous occupant.
        tok, self.caches = self._admit_step(
            self.params["base"], self.registry.store,
            self.registry.fusion_masks, self._fresh_row, self.caches,
            tokens, jnp.int32(aslot), jnp.int32(slot))
        first = int(tok[0])
        self.active[slot] = True
        self.pos[slot] = len(req.prompt)
        self.remaining[slot] = req.max_new_tokens
        self.adapter_idx[slot] = aslot
        self.rids[slot] = req.rid
        self.cur[slot, 0] = first
        self.outputs[req.rid].append(first)
        self.remaining[slot] -= 1
        if self.remaining[slot] <= 0:
            self._retire(slot)

    def _retire(self, slot: int) -> None:
        rid = self.rids[slot]
        self.latency[rid] = (time.perf_counter()
                             - self._submit_times.get(rid, 0.0))
        self.active[slot] = False
        self.rids[slot] = None

    # -- decode loop -------------------------------------------------------

    def step(self) -> int:
        """Admit what fits, run one batched decode step; -> #active rows."""
        for slot in range(self.B):
            if not self.active[slot] and self.queue:
                self._admit(slot, self.queue.pop(0))
        if not self.active.any():
            return 0
        t0 = time.perf_counter()
        tok, self.caches = self._decode(
            self.params["base"], self.registry.store,
            self.registry.fusion_masks, self.caches,
            jnp.asarray(self.cur), jnp.asarray(self.pos),
            jnp.asarray(self.adapter_idx))
        nxt = np.asarray(tok)
        self.step_times.append(time.perf_counter() - t0)
        for slot in range(self.B):
            if not self.active[slot]:
                continue
            self.pos[slot] += 1
            self.cur[slot, 0] = nxt[slot]
            self.outputs[self.rids[slot]].append(int(nxt[slot]))
            self.remaining[slot] -= 1
            if (self.remaining[slot] <= 0
                    or self.pos[slot] >= self.max_len - 1):
                self._retire(slot)
        return int(self.active.sum())

    def run(self) -> dict:
        """Drain queue + active rows; -> outputs and timing stats."""
        t0 = time.perf_counter()
        n_steps = 0
        while self.queue or self.active.any():
            self.step()
            n_steps += 1
        wall = time.perf_counter() - t0
        n_tok = sum(len(v) for v in self.outputs.values())
        lat = sorted(self.latency.values()) or [0.0]
        return {
            "outputs": dict(self.outputs),
            "n_steps": n_steps,
            "wall_s": wall,
            "generated_tokens": n_tok,
            "tok_s": n_tok / max(wall, 1e-9),
            "latency_p50_s": lat[len(lat) // 2],
            "latency_p99_s": lat[min(len(lat) - 1,
                                     int(np.ceil(0.99 * len(lat))) - 1)],
            "decode_step_times": list(self.step_times),
        }


# ---------------------------------------------------------------------------
# naive baseline: one merged model per request, sequential
# ---------------------------------------------------------------------------


def naive_serve(params: dict, cfg: ModelConfig, registry: AdapterRegistry,
                requests: list[Request], max_len: int) -> dict:
    """Per-request decode with merged single-adapter params — what serving
    N personalized clients costs without the gathered batched path. The
    per-step functions are jitted (cached per prompt length) so the
    comparison against the engine isolates batching + gathering, not
    dispatch overhead."""
    _prefill = _single_prefill_fn(cfg)
    _decode = _single_decode_fn(cfg)
    fresh = api.init_caches(cfg, 1, max_len)
    outputs: dict[str, list[int]] = {}
    t0 = time.perf_counter()
    for req in requests:
        aslot = jnp.int32(registry.slot(req.adapter))
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        tok, caches = _prefill(params["base"], registry.store,
                               registry.fusion_masks, fresh, tokens, aslot)
        toks = [int(tok[0])]
        pos = len(req.prompt)
        while len(toks) < req.max_new_tokens and pos < max_len - 1:
            cur = jnp.asarray([[toks[-1]]], jnp.int32)
            tok, caches = _decode(params["base"], registry.store,
                                  registry.fusion_masks, caches, cur,
                                  jnp.int32(pos), aslot)
            toks.append(int(tok[0]))
            pos += 1
        outputs[req.rid] = toks
    wall = time.perf_counter() - t0
    n_tok = sum(len(v) for v in outputs.values())
    return {"outputs": outputs, "wall_s": wall, "generated_tokens": n_tok,
            "tok_s": n_tok / max(wall, 1e-9)}
