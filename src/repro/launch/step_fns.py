"""Step functions lowered by the dry-run and executed by train.py/serve.py.

  train_step    LoRA fine-tuning (paper setting; frozen base) or full-param,
                Adam, grad-clip; returns (params, opt_state, metrics)
  prefill_step  full forward, returns last-position logits
  serve_step    one-token decode against the KV/SSM caches, greedy sample

All are pure functions of (cfg,) closed over — the dry-run lowers them with
ShapeDtypeStruct arguments and NamedSharding in_shardings.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api
from repro.optim import adam_init, adam_update

Array = jax.Array


def split_trainable(params: dict, mode: str) -> tuple[Any, Any]:
    if mode == "lora":
        return params["lora"], {"base": params["base"]}
    return params, {}


def merge_trainable(trainable: Any, rest: Any, mode: str) -> dict:
    if mode == "lora":
        return {"base": rest["base"], "lora": trainable}
    return trainable


def make_train_step(cfg: ModelConfig, lr: float = 1e-3,
                    train_mode: str = "lora", clip: float = 1.0):
    def train_step(params: dict, opt_state: dict, batch: dict):
        trainable, rest = split_trainable(params, train_mode)

        def loss_fn(tr):
            return api.loss_fn(merge_trainable(tr, rest, train_mode), cfg,
                               batch)

        loss, grads = jax.value_and_grad(loss_fn)(trainable)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        new_tr, new_opt = adam_update(trainable, grads, opt_state, lr)
        return (merge_trainable(new_tr, rest, train_mode), new_opt,
                {"loss": loss, "grad_norm": gnorm})

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params: dict, batch: dict):
        # unembed ONLY the final position: full-sequence logits at 32k x
        # 50-256k vocab dominated every prefill cell's memory/bytes
        # (§Perf log, "global baseline fixes")
        h, _, _ = api.forward_hidden(params, cfg, batch)
        logits = api.TF.unembed(params, cfg, h[:, -1:])
        return logits[:, 0]

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params: dict, caches: Any, token: Array, pos: Array):
        logits, new_caches = api.decode_step(params, cfg, caches, token, pos)
        next_token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_token, new_caches

    return serve_step


def abstract_params(cfg: ModelConfig, with_lora: bool = True):
    """ShapeDtypeStruct param tree — no allocation (dry-run)."""
    return jax.eval_shape(
        functools.partial(api.init_model, cfg=cfg, with_lora=with_lora),
        jax.random.PRNGKey(0))


def abstract_opt_state(trainable_abstract):
    return jax.eval_shape(adam_init, trainable_abstract)


def abstract_caches(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(
        functools.partial(api.init_caches, cfg, batch, max_len))
