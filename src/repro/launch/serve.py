"""Serving launcher: batched prefill + decode with a KV/SSM cache on the
host mesh. Demonstrates the serve path end-to-end (continuous greedy decode
over a batch of synthetic prompts) for any assigned architecture.

Usage:
  python -m repro.launch.serve --arch hymba-1.5b --smoke --prompt-len 64 \
      --decode-steps 32 --batch 4
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import base
    from repro.launch import step_fns as SF
    from repro.models import api

    mod = base.get_arch(args.arch)
    cfg = mod.SMOKE if args.smoke else mod.FULL
    key = jax.random.PRNGKey(args.seed)
    params = api.init_model(key, cfg)
    B, P = args.batch, args.prompt_len
    max_len = P + args.decode_steps

    tok_shape = (B, P, cfg.n_codebooks) if cfg.n_codebooks else (B, P)
    prompts = jax.random.randint(key, tok_shape, 0, cfg.vocab)

    serve_step = jax.jit(SF.make_serve_step(cfg))
    caches = api.init_caches(cfg, B, max_len)

    # prefill token-by-token through the cache path (uniform across
    # families; production prefill for attention archs uses the chunked
    # forward — benchmarked in the dry-run's prefill cells)
    t0 = time.time()
    tok = prompts[:, :1]
    for pos in range(P):
        tok_in = prompts[:, pos:pos + 1]
        tok, caches = serve_step(params, caches, tok_in, jnp.int32(pos))
    t_prefill = time.time() - t0

    out = []
    t0 = time.time()
    for pos in range(P, max_len):
        tok, caches = serve_step(params, caches, tok, jnp.int32(pos))
        out.append(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    tps = args.decode_steps * B / max(t_decode, 1e-9)
    print(f"[serve] {args.arch}: prefill {P} toks in {t_prefill:.2f}s; "
          f"decoded {args.decode_steps}x{B} in {t_decode:.2f}s "
          f"({tps:.1f} tok/s)")
    print("[serve] sample:", gen[0].reshape(-1)[:16].tolist())
    assert bool(jnp.all(gen >= 0)) and bool(jnp.all(gen < cfg.vocab))
    return gen


if __name__ == "__main__":
    main()
