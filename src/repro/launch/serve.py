"""Serving launcher: batched prefill + decode with a KV/SSM cache on the
host mesh. Demonstrates the serve path end-to-end (continuous greedy decode
over a batch of synthetic prompts) for any assigned architecture.

Prefill goes through ``models/api.prefill_with_cache``: attention archs run
one chunked forward over the whole prompt (P-fold fewer dispatches than the
historical per-token loop); recurrent archs (ssm/hybrid) keep the exact
token loop their state recurrence requires.

``--engine`` demos the continuous-batching multi-LoRA path instead: N
personalized adapters, requests joining/leaving the decode batch mid-stream
(launch/serving_engine.py).

Usage:
  python -m repro.launch.serve --arch hymba-1.5b --smoke --prompt-len 64 \
      --decode-steps 32 --batch 4
  python -m repro.launch.serve --arch phi3-medium-14b --smoke --engine \
      --n-adapters 4 --batch 4
"""
from __future__ import annotations

import argparse
import time


def _run_engine(args, cfg):
    import jax
    import numpy as np

    from repro.launch.serving_engine import (AdapterRegistry, Request,
                                             ServingEngine)
    from repro.models import api

    key = jax.random.PRNGKey(args.seed)
    params = api.init_model(key, cfg)
    rng = np.random.default_rng(args.seed)
    reg = AdapterRegistry(jax.random.PRNGKey(1), cfg,
                          capacity=args.n_adapters)
    n_blocks = len(reg.block_dims)
    for i in range(args.n_adapters):
        lora = api.init_model(jax.random.PRNGKey(100 + i), cfg)["lora"]
        mm = (rng.random(n_blocks) < 0.8).astype(np.float32)
        mm[int(rng.integers(n_blocks))] = 1.0  # >=1 modality present
        reg.register(f"client-{i}", lora, modality_mask=mm)

    max_len = args.prompt_len + args.decode_steps + 2
    eng = ServingEngine(params, cfg, reg, batch_slots=args.batch,
                        max_len=max_len)
    for r in range(args.batch * 2):  # 2x oversubscribed: slots recycle
        plen = int(rng.integers(max(2, args.prompt_len // 2),
                                args.prompt_len + 1))
        eng.submit(Request(
            rid=f"req-{r}", prompt=rng.integers(0, cfg.vocab, plen),
            adapter=f"client-{r % args.n_adapters}",
            max_new_tokens=args.decode_steps))
    res = eng.run()
    print(f"[serve/engine] {args.arch}: {len(res['outputs'])} requests, "
          f"{res['generated_tokens']} tokens in {res['wall_s']:.2f}s "
          f"({res['tok_s']:.1f} tok/s, p50 {res['latency_p50_s']:.3f}s, "
          f"p99 {res['latency_p99_s']:.3f}s)")
    sample = next(iter(res["outputs"].values()))
    print("[serve/engine] sample:", sample[:16])
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching multi-LoRA engine demo")
    ap.add_argument("--n-adapters", type=int, default=4)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import base
    from repro.launch import step_fns as SF
    from repro.models import api

    mod = base.get_arch(args.arch)
    cfg = mod.SMOKE if args.smoke else mod.FULL
    if args.engine:
        return _run_engine(args, cfg)
    key = jax.random.PRNGKey(args.seed)
    params = api.init_model(key, cfg)
    B, P = args.batch, args.prompt_len
    max_len = P + args.decode_steps

    tok_shape = (B, P, cfg.n_codebooks) if cfg.n_codebooks else (B, P)
    prompts = jax.random.randint(key, tok_shape, 0, cfg.vocab)

    serve_step = jax.jit(SF.make_serve_step(cfg))
    caches = api.init_caches(cfg, B, max_len)

    # chunked prefill (attention archs: one forward; ssm/hybrid: the cache
    # path is the recurrence, so api falls back to the exact token loop)
    t0 = time.time()
    logits, caches = api.prefill_with_cache(params, cfg, caches, prompts)
    tok = jnp.argmax(logits, axis=-1).astype(prompts.dtype)
    if cfg.n_codebooks:
        tok = tok.reshape(B, 1, cfg.n_codebooks)
    t_prefill = time.time() - t0

    out = []
    t0 = time.time()
    for pos in range(P, max_len):
        tok, caches = serve_step(params, caches, tok, jnp.int32(pos))
        out.append(tok)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    tps = args.decode_steps * B / max(t_decode, 1e-9)
    print(f"[serve] {args.arch}: prefill {P} toks in {t_prefill:.2f}s; "
          f"decoded {args.decode_steps}x{B} in {t_decode:.2f}s "
          f"({tps:.1f} tok/s)")
    print("[serve] sample:", gen[0].reshape(-1)[:16].tolist())
    assert bool(jnp.all(gen >= 0)) and bool(jnp.all(gen < cfg.vocab))
    return gen


if __name__ == "__main__":
    main()
