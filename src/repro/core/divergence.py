"""Cohort-internal divergence tracking (paper Eq. 5-6).

d_j^r = (1/|C_j|) * sum_{n in C_j} || delta_{j,n} - mean_{C_j}(delta_j) ||_F^2

computed per parameter group in one pass over the stacked client deltas, then
EMA-smoothed (Eq. 6). On the TPU mesh this is the fused masked-variance
reduction implemented by kernels/cohort_agg; here is the XLA/reference path.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import mdlora

Array = jax.Array


def group_divergence(layout: mdlora.GroupLayout, deltas: Any,
                     cohort: Array) -> Array:
    """deltas: client-stacked trainable pytree ([N, ...] leaves);
    cohort: [N, G] bool/float — who contributes to each group's estimate.
    -> [G] float32 divergences."""
    c = jnp.asarray(cohort, jnp.float32)
    counts = jnp.sum(c, axis=0)  # [G]
    Wmean = jnp.where(counts[None, :] > 0,
                      c / jnp.maximum(counts[None, :], 1.0), 0.0)
    mean_tree = mdlora.weighted_combine(layout, deltas, Wmean)

    # sum over cohort of ||delta_n - mean||^2, per group
    dev = jax.tree.map(
        lambda d, m: d.astype(jnp.float32) - m[None], deltas, mean_tree)
    # per-client per-group squared norms
    per_client = jax.vmap(lambda t: mdlora.group_norms(layout, t))(dev)  # [N,G]
    tot = jnp.sum(per_client * c, axis=0)
    return jnp.where(counts > 0, tot / jnp.maximum(counts, 1.0), 0.0)


def ema_update(dbar: Array, d: Array, gamma: float) -> Array:
    """Eq. 6: dbar^r = gamma*d^r + (1-gamma)*dbar^{r-1}."""
    return gamma * d + (1.0 - gamma) * dbar


def ema_bias_bound(gamma: float, delta_max: float) -> float:
    """Steady-state EMA tracking bias bound (Prop. 5 / Eq. 21, CORRECTED).

    Unrolling dbar^r = gamma * sum_s (1-gamma)^s d^{r-s} and using
    |d^{r-s} - d^r| <= s*delta gives
        |dbar - d| <= gamma*delta * sum_{s>=1} s(1-gamma)^s
                    = gamma*delta * (1-gamma)/gamma^2 = delta*(1-gamma)/gamma.
    The paper states gamma*delta/(1-gamma)^2, which mis-evaluates the
    arithmetico-geometric series (sum s*x^s = x/(1-x)^2 evaluated at
    x = 1-gamma); empirically the paper's constant is violated for
    gamma < 1/2 (see tests/test_core_relief.py::test_ema_bias_bound and
    EXPERIMENTS.md §Repro-findings). The O(sqrt(R)) regret *form* of
    Prop. 5 is unaffected — only the constant changes.
    """
    return delta_max * (1.0 - gamma) / gamma


def ema_bias_bound_paper(gamma: float, delta_max: float) -> float:
    """The bound exactly as printed in the paper (Eq. 21) — kept for the
    comparison test documenting the discrepancy."""
    return gamma * delta_max / (1.0 - gamma) ** 2
