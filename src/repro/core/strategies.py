"""Federated strategies: RELIEF (+ its ablations V1-V3) and the paper's ten
baselines, expressed as combinations of four orthogonal knobs consumed by the
engine:

  alloc     what to train        all | all_groups | divergence | magnitude |
                                 random | depth
  budgets   how much to train    elastic (Eq. 7) | none
  agg       how to aggregate     cohort (Eq. 3-4) | fedavg | dimension |
                                 helora
  personal  what stays local     leaf-path substrings never aggregated
                                 (+ optional cluster mixing)

Baseline fidelity note (DESIGN.md §7): baselines are *protocol-level*
reimplementations of the published mechanisms (what is trained, how updates
are aggregated, what is communicated); system-specific engineering from the
original papers (e.g. FedEL's window scheduler internals, DarkDistill's
distillation temperature) is approximated by the nearest protocol with the
same selection semantics — each docstring states the approximation.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Strategy:
    name: str
    # which groups each client trains:
    #   full       — the whole model, incl. absent-modality parameters
    #                (classical FL: the paper's Q2 waste mechanism)
    #   accessible — only groups of owned modalities (modality-aware)
    #   divergence/magnitude/random/depth — scored top-k within budget
    alloc: str = "full"
    budgets: str = "none"  # elastic (Eq.7) | none
    agg: str = "fedavg"  # cohort | fedavg | dimension | helora
    mandatory: bool = False  # enforce {A_m : m in M_n} inclusion
    prox_mu: float = 0.0  # FedProx proximal coefficient
    personal: tuple[str, ...] = ()  # leaf substrings kept local
    cluster_mix: bool = False  # personal leaves mixed within modality clusters
    rank_caps: tuple[float, ...] = ()  # HeLoRA per-type rank fractions
    share_only: tuple[str, ...] = ()  # if set, aggregate ONLY these leaves
    depth_rotate: bool = False  # FedICU: rotate depth window per round


def relief(**kw) -> Strategy:
    """V0 — full RELIEF: divergence-guided elastic + cohort aggregation."""
    return Strategy("relief", alloc="divergence", budgets="elastic",
                    agg="cohort", mandatory=True, **kw)


def relief_no_elastic() -> Strategy:
    """V1 — cohort aggregation only (trains everything accessible)."""
    return Strategy("relief_v1_no_elastic", alloc="accessible", budgets="none",
                    agg="cohort", mandatory=True)


def relief_no_cohort() -> Strategy:
    """V2 — elastic only, naive FedAvg aggregation (no mandatory set, same
    budget => paper notes V2/V3 speedup exceeds V0)."""
    return Strategy("relief_v2_no_cohort", alloc="divergence",
                    budgets="elastic", agg="fedavg", mandatory=False)


def relief_random_alloc() -> Strategy:
    """V3 — random allocation at the same budgets, cohort aggregation."""
    return Strategy("relief_v3_random", alloc="random", budgets="elastic",
                    agg="cohort", mandatory=False)


def fedavg() -> Strategy:
    """McMahan et al. — full local training, uniform averaging."""
    return Strategy("fedavg", alloc="full", agg="fedavg")


def fedprox(mu: float = 0.01) -> Strategy:
    """Li et al. — FedAvg + proximal term mu/2 ||theta - theta^r||^2."""
    return Strategy("fedprox", alloc="full", agg="fedavg", prox_mu=mu)


def fedel_like() -> Strategy:
    """FedEL (Zhang et al.): elastic tensor selection by update magnitude
    within a runtime budget. Modality-UNAWARE: candidates include groups for
    absent sensors (candidates = ALL groups), reproducing the paper's zero-gradient
    waste. Approximates the sliding-window scheduler by magnitude top-k."""
    return Strategy("fedel", alloc="magnitude", budgets="elastic",
                    agg="fedavg", mandatory=False)


def fedicu_like() -> Strategy:
    """FedICU (Liao et al.): importance-aware model splitting — weak devices
    train a contiguous depth window that rotates across rounds; plain
    averaging. Approximates importance scoring by round-robin coverage."""
    return Strategy("fedicu", alloc="depth", budgets="elastic", agg="fedavg",
                    depth_rotate=True)


def darkdistill_like() -> Strategy:
    """DarkDistill (Qu et al.): difficulty-aligned early-exit training —
    weak devices train the shallow prefix + head (fixed depth prefix, no
    rotation); distillation between exits is not modeled."""
    return Strategy("darkdistill", alloc="depth", budgets="elastic",
                    agg="fedavg")


def harmony_like() -> Strategy:
    """Harmony (Ouyang et al.): modality-wise federation; the fusion layer
    (and head) are NOT federated — they remain local to each device."""
    return Strategy("harmony", alloc="accessible", agg="cohort",
                    personal=("fusion", "head"))


def pilot_like() -> Strategy:
    """Pilot / FediLoRA-style dimension-wise aggregation: each row of the
    fusion projection is averaged over the clients with a non-zero update
    (cohort-aware rows) but without RELIEF's B-weighting or elastic budget."""
    return Strategy("pilot", alloc="accessible", agg="dimension")


def fedsa_lora() -> Strategy:
    """FedSA-LoRA (Guo et al.): share only the A matrices (input-side,
    ``['a']`` leaves in our storage); B matrices stay local."""
    return Strategy("fedsa_lora", alloc="full", agg="fedavg",
                    share_only=("['a']", "head"))


def helora_like(rank_caps=(1.0, 0.5, 0.25)) -> Strategy:
    """HeLoRA (Fan et al.): heterogeneous LoRA ranks by device tier
    (full/mid/low fractions of rho); zero-pad reconciliation at the server
    = rank-masked elementwise mean."""
    return Strategy("helora", alloc="full", agg="helora", rank_caps=rank_caps)


def fedlease_like() -> Strategy:
    """FedLEASE (Wang et al.): clients clustered by representation
    similarity get cluster-expert adapters; we cluster by modality-set
    identity (the dominant similarity factor here) and aggregate adapter
    leaves within clusters."""
    return Strategy("fedlease", alloc="full", agg="fedavg",
                    personal=("lora",), cluster_mix=True)


# ---------------------------------------------------------------------------
# asynchronous (event-driven) strategies — consumed by core/async_engine.py
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AsyncStrategy(Strategy):
    """Strategy + the event-driven runtime's knobs.

    buffer_size         K: server aggregates once K completions are buffered
                        (FedBuff-style). K = N with a homogeneous fleet
                        degenerates to the synchronous engine.
    staleness_exponent  a in the polynomial discount 1/(1+s)^a, s = server
                        versions elapsed since the client pulled its model.
                        a = 0 disables discounting.
    max_staleness       drop (never aggregate) updates staler than this;
                        None = keep everything.
    """
    buffer_size: int = 4
    staleness_exponent: float = 0.5
    max_staleness: int | None = None
    # Byzantine-robust within-cohort reduction (core/aggregation.py):
    #   mean | trimmed | median | krum. Non-mean modes reduce whole flush
    #   cohorts with bounded-breakdown estimators; see robust_combine.
    robust: str = "mean"
    trim_frac: float = 0.1  # beta for robust="trimmed"
    krum_f: int = 1  # assumed Byzantine count per cohort for robust="krum"
    # FedMFS-style selective modality communication (arXiv:2310.07048):
    # after local training, upload ONLY the modality-block deltas whose
    # Shapley-style utility-per-byte clears a greedy knapsack under
    # comm_budget x (full upload bytes). Compute cost is unchanged; the
    # server aggregates the shrunk upload set.
    selective: bool = False
    comm_budget: float = 0.5  # fraction of the trained-set upload bytes kept


def async_relief(buffer_size: int = 4, staleness_exponent: float = 0.5,
                 **kw) -> AsyncStrategy:
    """RELIEF's allocation + cohort aggregation on the async runtime."""
    return AsyncStrategy("async_relief", alloc="divergence",
                         budgets="elastic", agg="cohort", mandatory=True,
                         buffer_size=buffer_size,
                         staleness_exponent=staleness_exponent, **kw)


def async_accessible(buffer_size: int = 4, staleness_exponent: float = 0.5,
                     **kw) -> AsyncStrategy:
    """Modality-aware async without elastic budgeting (V1 analog)."""
    return AsyncStrategy("async_accessible", alloc="accessible",
                         budgets="none", agg="cohort", mandatory=True,
                         buffer_size=buffer_size,
                         staleness_exponent=staleness_exponent, **kw)


def async_fedbuff(buffer_size: int = 4, staleness_exponent: float = 0.5,
                  **kw) -> AsyncStrategy:
    """FedBuff (Nguyen et al.): modality-UNAWARE buffered async FedAvg —
    every buffered client averaged into every group with the staleness
    discount as its only weighting."""
    return AsyncStrategy("async_fedbuff", alloc="full", budgets="none",
                         agg="fedavg", buffer_size=buffer_size,
                         staleness_exponent=staleness_exponent, **kw)


def relief_trimmed(trim_frac: float = 0.1, **kw) -> AsyncStrategy:
    """async_relief with beta-trimmed-mean cohort reduction. Cheapest robust
    rule; keeps combine weights; breaks down past ~trim_frac Byzantine."""
    return AsyncStrategy("relief_trimmed", alloc="divergence",
                         budgets="elastic", agg="cohort", mandatory=True,
                         robust="trimmed", trim_frac=trim_frac, **kw)


def relief_median(**kw) -> AsyncStrategy:
    """async_relief with coordinate-median cohort reduction. Breakdown point
    1/2 per coordinate; ignores combine weights (every member counts once)."""
    return AsyncStrategy("relief_median", alloc="divergence",
                         budgets="elastic", agg="cohort", mandatory=True,
                         robust="median", **kw)


def relief_krum(krum_f: int = 1, **kw) -> AsyncStrategy:
    """async_relief with blockwise Krum cohort reduction: per modality group,
    the single member delta closest to its k-f-2 nearest co-members is taken
    verbatim. Strongest against collusion (never mixes attacker mass in);
    assumes cohorts of at least f+3 members to be selective."""
    return AsyncStrategy("relief_krum", alloc="divergence",
                         budgets="elastic", agg="cohort", mandatory=True,
                         robust="krum", krum_f=krum_f, **kw)


def fedmfs_selective(comm_budget: float = 0.5, **kw) -> AsyncStrategy:
    """FedMFS (Yuan et al., arXiv:2310.07048): modality-aware local training
    with *selective modality-block upload* — each client ranks its trained
    blocks by Shapley-style utility per byte (||delta_g||^2 / bytes_g, the
    marginal-contribution proxy) and uploads greedily until the byte budget
    is spent. No elastic compute budgeting: the selection is purely a
    communication mechanism layered on accessible allocation."""
    return AsyncStrategy("fedmfs_selective", alloc="accessible",
                         budgets="none", agg="cohort", mandatory=True,
                         selective=True, comm_budget=comm_budget, **kw)


def relief_selective(comm_budget: float = 0.5, **kw) -> AsyncStrategy:
    """async_relief + FedMFS selective upload: divergence-guided elastic
    compute allocation AND utility-per-byte upload pruning."""
    return AsyncStrategy("relief_selective", alloc="divergence",
                         budgets="elastic", agg="cohort", mandatory=True,
                         selective=True, comm_budget=comm_budget, **kw)


ASYNC_STRATEGIES = {
    "async_relief": async_relief, "async_accessible": async_accessible,
    "async_fedbuff": async_fedbuff, "relief_trimmed": relief_trimmed,
    "relief_median": relief_median, "relief_krum": relief_krum,
    "fedmfs_selective": fedmfs_selective,
    "relief_selective": relief_selective,
}


ALL_BASELINES = {
    "fedavg": fedavg, "fedprox": fedprox, "fedel": fedel_like,
    "fedicu": fedicu_like, "darkdistill": darkdistill_like,
    "harmony": harmony_like, "pilot": pilot_like, "fedsa_lora": fedsa_lora,
    "helora": helora_like, "fedlease": fedlease_like,
}

ABLATIONS = {
    "v0": relief, "v1": relief_no_elastic, "v2": relief_no_cohort,
    "v3": relief_random_alloc,
}


# ---------------------------------------------------------------------------
# name-keyed registry — the single lookup surface for benchmarks/examples/
# scenarios; the factory callables above remain as thin aliases
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, object] = {
    "relief": relief, "v0": relief, "v1": relief_no_elastic,
    "v2": relief_no_cohort, "v3": relief_random_alloc,
    **ALL_BASELINES, **ASYNC_STRATEGIES,
}


def register(name: str, factory) -> None:
    """Add a zero-arg (or all-defaults) Strategy factory under ``name``."""
    _REGISTRY[name] = factory


def names() -> list[str]:
    """Registered strategy names (aliases like ``v0`` included)."""
    return sorted(_REGISTRY)


def get(name: str, **overrides) -> Strategy:
    """Look up a strategy by name, optionally overriding any dataclass field:

        strategies.get("relief_trimmed", trim_frac=0.2, buffer_size=8)

    Overrides apply via ``dataclasses.replace`` on the factory's default
    instance, so any field of Strategy/AsyncStrategy can be set — unknown
    fields raise TypeError, unknown names raise ValueError."""
    if name not in _REGISTRY:
        raise ValueError(f"unknown strategy {name!r}; known: {names()}")
    strat = _REGISTRY[name]()
    return dataclasses.replace(strat, **overrides) if overrides else strat


def get_strategy(name: str) -> Strategy:
    """Deprecated alias for :func:`get` (kept for older scripts)."""
    return get(name)
