"""MDLoRA: modality-aligned column-block structure (paper Eq. 1) and the
parameter-group layout that is RELIEF's *unified interface* for aggregation,
elastic training and communication.

The fusion-layer LoRA projection A in R^{rho x D} (stored transposed as
``a: [D, rho]``) is partitioned into M contiguous blocks along D, one per
modality. All trainable parameters are organized into G groups
(paper Sec. III-B):

    G = M fusion blocks + 1 shared B + sum_m L_m encoder groups + L_H head

A ``GroupLayout`` indexes every trainable leaf (or row-range of the fusion
``a`` leaf, or axis-0 slice of a layer-stacked leaf) to a group id and
carries per-group metadata (kind, modality, size, flops). Everything
downstream — cohort-wise aggregation (Eq. 3-4), divergence (Eq. 5), elastic
allocation (Eq. 7), on-demand upload (Eq. 8) and the timing/energy simulator
— consumes this one structure.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

KIND_FUSION_BLOCK = "fusion_block"
KIND_FUSION_B = "fusion_b"
KIND_ENCODER = "encoder"
KIND_HEAD = "head"


def path_str(path) -> str:
    return jax.tree_util.keystr(path)


@dataclasses.dataclass
class GroupLayout:
    names: list[str]
    kinds: list[str]
    modality: np.ndarray  # [G] int, -1 for none
    sizes: np.ndarray  # [G] param counts
    flops: np.ndarray  # [G] relative per-round training cost
    leaf_group: dict[str, int]  # whole-leaf path -> group id
    leaf_axis0_groups: dict[str, np.ndarray]  # stacked leaf -> per-slice gid
    fusion_a_path: str | None  # the row-blocked leaf
    fusion_rows: list[tuple[int, int, int]]  # (row_start, row_end, group_id)
    n_modalities: int

    @property
    def G(self) -> int:
        return len(self.names)

    def group_ids(self, kind: str) -> np.ndarray:
        return np.array([i for i, k in enumerate(self.kinds) if k == kind],
                        np.int32)

    # -- vectorized fleet helpers --------------------------------------------

    def accessible(self, modality_mask: np.ndarray) -> np.ndarray:
        """modality_mask: [N, M] -> accessible groups G_n: [N, G] bool."""
        mm = np.asarray(modality_mask, bool)
        out = np.zeros((mm.shape[0], self.G), bool)
        for g in range(self.G):
            if self.sizes[g] == 0:  # empty group (e.g. no B matrix in B1)
                continue
            m = self.modality[g]
            out[:, g] = True if m < 0 else mm[:, m]
        return out

    def mandatory(self, modality_mask: np.ndarray) -> np.ndarray:
        """Mandatory inclusion {A_m : m in M_n} (paper IV-B2b): [N, G]."""
        mm = np.asarray(modality_mask, bool)
        out = np.zeros((mm.shape[0], self.G), bool)
        for g in range(self.G):
            if self.kinds[g] == KIND_FUSION_BLOCK:
                out[:, g] = mm[:, self.modality[g]]
        return out

    def row_group_vector(self, D: int) -> np.ndarray:
        """[D] group id per row of the fusion ``a`` leaf."""
        rg = np.zeros(D, np.int32)
        for s, e, g in self.fusion_rows:
            rg[s:e] = g
        return rg


# ---------------------------------------------------------------------------
# layout construction for the multimodal model (models/multimodal.py)
# ---------------------------------------------------------------------------


def mm_group_layout(cfg, trainable: dict) -> GroupLayout:
    """Build the paper's G-group layout from an MMConfig + a trainable
    subtree (full params for Backbone 1; {lora, head} for Backbone 2)."""
    names: list[str] = []
    kinds: list[str] = []
    modality: list[int] = []
    sizes: list[int] = []
    leaf_group: dict[str, int] = {}
    leaf_axis0_groups: dict[str, np.ndarray] = {}
    fusion_rows: list[tuple[int, int, int]] = []
    fusion_a_path: str | None = None

    def new_group(name, kind, mod):
        names.append(name)
        kinds.append(kind)
        modality.append(mod)
        sizes.append(0)
        return len(names) - 1

    # fusion blocks first (stable ids 0..M-1), then B
    off = 0
    for i, m in enumerate(cfg.modalities):
        g = new_group(f"A_{m.name}", KIND_FUSION_BLOCK, i)
        fusion_rows.append((off, off + m.d_feat, g))
        off += m.d_feat
    b_gid = new_group("B_shared", KIND_FUSION_B, -1)

    leaves = jax.tree_util.tree_flatten_with_path(trainable)[0]
    mod_index = {m.name: i for i, m in enumerate(cfg.modalities)}
    enc_groups: dict[tuple[int, str], int] = {}
    head_groups: dict[str, int] = {}

    for path, leaf in leaves:
        p = path_str(path)
        is_fusion = "fusion" in p
        if is_fusion and p.endswith("['a']"):
            fusion_a_path = p
            rho = leaf.shape[1]
            for s, e, g in fusion_rows:
                sizes[g] += (e - s) * rho
            continue
        if "fusion_w0" in p:  # Backbone 1: the FC weight itself is blocked
            fusion_a_path = p
            dout = leaf.shape[1]
            for s, e, g in fusion_rows:
                sizes[g] += (e - s) * dout
            continue
        if is_fusion and p.endswith("['b']"):
            leaf_group[p] = b_gid
            sizes[b_gid] += leaf.size
            continue
        enc_mod = next((mod_index[nm] for nm in mod_index
                        if f"['{nm}']" in p), None)
        if enc_mod is not None:
            mname = cfg.modalities[enc_mod].name
            if "layers" in p:  # layer-stacked leaf: one group per layer slice
                n_l = leaf.shape[0]
                gids = []
                for l in range(n_l):
                    kk = (enc_mod, f"L{l}")
                    if kk not in enc_groups:
                        enc_groups[kk] = new_group(f"E_{mname}_L{l}",
                                                   KIND_ENCODER, enc_mod)
                    gids.append(enc_groups[kk])
                    sizes[enc_groups[kk]] += leaf.size // n_l
                leaf_axis0_groups[p] = np.array(gids, np.int32)
            else:  # per-module leaf (conv1/conv2/proj/patch)
                toks = re.findall(r"\['(\w+)'\]", p)
                label = toks[min(toks.index(mname) + 1, len(toks) - 1)]
                kk = (enc_mod, label)
                if kk not in enc_groups:
                    enc_groups[kk] = new_group(f"E_{mname}_{label}",
                                               KIND_ENCODER, enc_mod)
                leaf_group[p] = enc_groups[kk]
                sizes[enc_groups[kk]] += leaf.size
            continue
        # head (and any remaining global leaf): one group per head layer
        label = re.findall(r"\['(\w+)'\]", p)[-1]
        if label not in head_groups:
            head_groups[label] = new_group(f"H_{label}", KIND_HEAD, -1)
        leaf_group[p] = head_groups[label]
        sizes[head_groups[label]] += leaf.size

    sizes_np = np.array(sizes, np.int64)
    flops = np.maximum(sizes_np.astype(np.float64), 1.0)
    return GroupLayout(names, kinds, np.array(modality, np.int32), sizes_np,
                       flops, leaf_group, leaf_axis0_groups, fusion_a_path,
                       fusion_rows, cfg.M)


# ---------------------------------------------------------------------------
# group-gated tree ops (vmap-able over a leading client axis)
# ---------------------------------------------------------------------------


def group_gate_tree(layout: GroupLayout, trainable: Any, gate: Array) -> Any:
    """gate: [G] float -> pytree like ``trainable`` with per-group gates
    applied (fusion ``a`` rows and stacked-layer slices get per-slice gates).
    Used to mask gradients (elastic training) and uploads (Eq. 8)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(trainable)
    out = []
    for path, leaf in leaves:
        p = path_str(path)
        if p == layout.fusion_a_path:
            rg = jnp.asarray(layout.row_group_vector(leaf.shape[0]))
            g = gate[rg].astype(leaf.dtype)
            out.append(leaf * g[:, None])
        elif p in layout.leaf_axis0_groups:
            ids = jnp.asarray(layout.leaf_axis0_groups[p])
            g = gate[ids].astype(leaf.dtype)
            out.append(leaf * g.reshape((-1,) + (1,) * (leaf.ndim - 1)))
        elif p in layout.leaf_group:
            out.append(leaf * gate[layout.leaf_group[p]].astype(leaf.dtype))
        else:
            out.append(leaf * 0)
    return jax.tree_util.tree_unflatten(treedef, out)


def group_norms(layout: GroupLayout, tree: Any) -> Array:
    """Per-group squared Frobenius norms: -> [G] float32."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    acc = jnp.zeros((layout.G,), jnp.float32)
    for path, leaf in leaves:
        p = path_str(path)
        x32 = leaf.astype(jnp.float32)
        if p == layout.fusion_a_path:
            rg = jnp.asarray(layout.row_group_vector(leaf.shape[0]))
            per_row = jnp.sum(jnp.square(x32), axis=tuple(range(1, leaf.ndim)))
            acc = acc.at[rg].add(per_row)
        elif p in layout.leaf_axis0_groups:
            ids = jnp.asarray(layout.leaf_axis0_groups[p])
            per_l = jnp.sum(jnp.square(x32), axis=tuple(range(1, leaf.ndim)))
            acc = acc.at[ids].add(per_l)
        elif p in layout.leaf_group:
            acc = acc.at[layout.leaf_group[p]].add(jnp.sum(jnp.square(x32)))
    return acc


def weighted_combine(layout: GroupLayout, deltas: Any, W: Array) -> Any:
    """Aggregate client-stacked deltas with per-(client, group) weights.

    deltas: pytree with leading client axis N on every leaf.
    W: [N, G] combine weights (rows need not sum to 1; caller normalizes).
    -> pytree without the client axis: sum_n W[n, g_leaf] * delta_n.
    """
    leaves, treedef = jax.tree_util.tree_flatten_with_path(deltas)
    out = []
    for path, leaf in leaves:
        p = path_str(path)
        x = leaf.astype(jnp.float32)
        if p == layout.fusion_a_path:
            rg = jnp.asarray(layout.row_group_vector(leaf.shape[1]))
            w = W[:, rg]  # [N, D]
            out.append(jnp.einsum("nd,nd...->d...", w, x))
        elif p in layout.leaf_axis0_groups:
            ids = jnp.asarray(layout.leaf_axis0_groups[p])
            w = W[:, ids]  # [N, L]
            out.append(jnp.einsum("nl,nl...->l...", w, x))
        elif p in layout.leaf_group:
            w = W[:, layout.leaf_group[p]]  # [N]
            out.append(jnp.einsum("n,n...->...", w, x))
        else:
            out.append(jnp.zeros(leaf.shape[1:], jnp.float32))
    return jax.tree_util.tree_unflatten(treedef, out)
