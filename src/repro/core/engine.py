"""The RELIEF round engine (paper Algorithm 1) and its baselines.

One round = (1) server allocation [blue]: EMA divergence -> Eq. 7 budgets ->
top-k group selection; (2) parallel local training [green]: clients run E
epochs with gradients gated to their assigned groups (vmapped over the client
axis — on a TPU mesh this axis is sharded, see dist/); (3) server aggregation
[orange]: cohort-wise masked means (Eq. 3-4) + divergence update (Eq. 5-6).

Fault tolerance: client participation is a per-round mask — any dropout
pattern yields well-defined aggregation (empty cohorts freeze their block);
the engine state (global trainable, divergence EMA, round index, rng) is
checkpointable via repro.checkpoint.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as AG
from repro.core import allocation as AL
from repro.core import divergence as DV
from repro.core import mdlora
from repro.core.strategies import Strategy
from repro.core.tasks import MMTask
from repro.optim import adam_init, adam_update
from repro.sim import FleetConfig
from repro.sim import timing as T

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FedConfig:
    rounds: int = 50
    local_epochs: int = 5  # E (paper VI-A3)
    steps_per_epoch: int = 4
    batch_size: int = 32
    lr: float = 1e-3
    gamma: float = 0.9  # EMA coefficient (Eq. 6)
    server_lr: float = 1.0
    participation: float = 1.0
    t_overhead: float = 0.05
    utilization: float = 0.3
    eval_every: int = 5
    seed: int = 0
    dropout_prob: float = 0.0  # random client failures (fault injection)
    # timing model: "flop_proportional" = the paper's Sec. VI-A3 simulator
    # (compute ~ trained-group FLOPs only; reproduces Tables I-II speedups);
    # "fwd_aware" = the Sec. VII-corrected model charging the fixed
    # full-model forward to everyone (reproduces the real-device gap).
    sim_mode: str = "flop_proportional"

    @classmethod
    def from_scenario(cls, spec, **overrides):
        """Training knobs from a ``sim.scenarios.ScenarioSpec`` (duck-typed:
        anything with the same field names works)."""
        return cls(**(scenario_fed_kwargs(spec) | overrides))


def scenario_fed_kwargs(spec) -> dict:
    """The FedConfig fields a ScenarioSpec carries, as constructor kwargs."""
    return dict(rounds=spec.rounds, local_epochs=spec.local_epochs,
                steps_per_epoch=spec.steps_per_epoch,
                batch_size=spec.batch_size, lr=spec.lr,
                eval_every=spec.eval_every, t_overhead=spec.t_overhead,
                utilization=spec.utilization, seed=spec.seed)


@dataclasses.dataclass
class FedState:
    round: int
    trainable: Any  # global trainable tree
    client_trainable: Any  # [N, ...] stacked (personalized leaves live here)
    dbar: np.ndarray  # [G] EMA divergence
    mag_ema: np.ndarray  # [G] update-magnitude EMA (FedEL-like alloc)
    rng: np.random.Generator


# ---------------------------------------------------------------------------
# compiled local-update kernel (shared by every strategy)
# ---------------------------------------------------------------------------


def make_local_update(task: MMTask, fed: FedConfig, prox_mu: float):
    layout = task.layout

    def one_client(start, batches, mmask, gate, rank_gate, lr):
        opt = adam_init(start)

        def step(carry, batch):
            tr, opt = carry
            b = dict(batch) | {"modality_mask": mmask}
            loss, grads = jax.value_and_grad(task.loss)(tr, b)
            if prox_mu > 0.0:
                grads = jax.tree.map(
                    lambda g, t, t0: g + prox_mu * (
                        t.astype(jnp.float32) - t0.astype(jnp.float32)),
                    grads, tr, start)
            grads = mdlora.group_gate_tree(layout, grads, gate)
            grads = jax.tree.map(lambda g, m: g * m, grads, rank_gate)
            tr, opt = adam_update(tr, grads, opt, lr)
            return (tr, opt), loss

        (tr, _), losses = jax.lax.scan(step, (start, opt), batches)
        delta = jax.tree.map(
            lambda a, b_: a.astype(jnp.float32) - b_.astype(jnp.float32),
            tr, start)
        delta = mdlora.group_gate_tree(layout, delta, gate)
        delta = jax.tree.map(lambda d, m: d * m, delta, rank_gate)
        return delta, jnp.mean(losses)

    return jax.jit(jax.vmap(one_client, in_axes=(0, 0, 0, 0, 0, None)))


# ---------------------------------------------------------------------------
# data plumbing (shared with the async runtime: identical rng call sequence
# per client => the sync-parity test is bit-for-bit)
# ---------------------------------------------------------------------------


def draw_client_batches(rng: np.random.Generator, dataset, clients,
                        steps: int, batch_size: int) -> dict:
    """Stacked local-training batches for ``clients`` (one rng.integers call
    per client, in iteration order)."""
    xs, ys = [], []
    for n in clients:
        src = n % len(dataset.train_y)
        idx = rng.integers(0, len(dataset.train_y[src]),
                           size=(steps, batch_size))
        xs.append(dataset.train_x[src][idx])
        ys.append(dataset.train_y[src][idx])
    return {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))}


# ---------------------------------------------------------------------------
# allocation dispatch
# ---------------------------------------------------------------------------


def _depth_order(layout: mdlora.GroupLayout) -> np.ndarray:
    """Shallow-to-deep group ordering for depth-based baselines."""
    def rank(i):
        n, k = layout.names[i], layout.kinds[i]
        if k == mdlora.KIND_ENCODER:
            lay = int(n.split("_L")[-1]) if "_L" in n else 0
            return (0, lay)
        if k == mdlora.KIND_FUSION_BLOCK:
            return (1, 0)
        if k == mdlora.KIND_FUSION_B:
            return (1, 1)
        return (2, 0)
    return np.array(sorted(range(layout.G), key=rank), np.int32)


@dataclasses.dataclass(frozen=True)
class AllocPlan:
    """Fleet-static inputs of allocation, precomputed once per run.

    Everything here depends only on (strategy, layout, fleet, fed): candidate
    and mandatory masks, and the elastic budgets (Eq. 7 — ``t_star`` is a
    fleet-wide binary search, so it must be solved over the FULL fleet even
    when only a dispatch batch is being allocated; caching it here is what
    makes per-batch allocation O(batch) instead of O(N))."""
    cand: np.ndarray  # [N, G] candidate groups
    mandatory: np.ndarray  # [N, G] forced inclusions
    k: np.ndarray  # [N] group budgets
    depth_order: np.ndarray | None = None  # [G] (depth baselines only)


def plan_allocation(strategy: Strategy, task: MMTask, fleet: FleetConfig,
                    fed: FedConfig, group_flops: np.ndarray) -> AllocPlan:
    layout = task.layout
    N, G = fleet.N, layout.G
    accessible = layout.accessible(fleet.modality_mask)
    if strategy.alloc in ("full", "magnitude", "depth"):
        # modality-unaware: every (non-empty) group is a training candidate —
        # classical FL trains absent-sensor parameters too (paper Q2)
        cand = np.tile(layout.sizes[None, :] > 0, (N, 1))
    else:
        cand = accessible
    mandatory = (layout.mandatory(fleet.modality_mask) if strategy.mandatory
                 else np.zeros((N, G), bool))
    n_mand = mandatory.sum(1)
    g_max = cand.sum(1)

    if strategy.budgets == "elastic":
        examples = fed.local_epochs * fed.steps_per_epoch * fed.batch_size
        tau = T.profile_tau(fleet, group_flops, examples, fed.utilization)
        t_star = AL.solve_t_star(tau, fed.t_overhead, n_mand, g_max)
        k = AL.elastic_budgets(tau, t_star, fed.t_overhead, n_mand, g_max)
    else:
        k = g_max.copy()
    order = _depth_order(layout) if strategy.alloc == "depth" else None
    return AllocPlan(cand, mandatory, k, order)


def allocate_rows(plan: AllocPlan, strategy: Strategy, state: FedState,
                  idx: np.ndarray, cand: np.ndarray | None = None,
                  mandatory: np.ndarray | None = None) -> np.ndarray:
    """S rows [len(idx), G] for the client subset ``idx``.

    Row-identical to ``allocate(...)[0][idx]`` for every deterministic
    allocator (scores are shared fleet-wide state, budgets come from the
    plan); ``alloc="random"`` draws fresh noise per call, so only
    whole-fleet calls reproduce the legacy stream.

    ``cand``/``mandatory`` ([len(idx), G]) override the plan's fleet-static
    masks — the hook for time-varying modality availability (streaming
    scenarios), where the candidate set is a function of dispatch time while
    the Eq. 7 budgets ``k`` stay solved over the base fleet."""
    idx = np.asarray(idx)
    cand = plan.cand[idx] if cand is None else np.asarray(cand, bool)
    mandatory = (plan.mandatory[idx] if mandatory is None
                 else np.asarray(mandatory, bool))
    k = plan.k[idx]
    if strategy.alloc in ("full", "accessible"):
        return cand
    if strategy.alloc == "divergence":
        score = state.dbar
    elif strategy.alloc == "magnitude":
        score = state.mag_ema
    elif strategy.alloc == "random":
        return AL.allocate_topk(state.dbar, cand, mandatory, k,
                                rng=state.rng, randomize=True)
    elif strategy.alloc == "depth":
        G = cand.shape[1]
        order = plan.depth_order
        S = np.zeros_like(cand)
        offset = (state.round % max(G, 1)) if strategy.depth_rotate else 0
        for n in range(len(idx)):
            take = [order[(offset + i) % G] for i in range(G)
                    if cand[n, order[(offset + i) % G]]][: int(k[n])]
            S[n, take] = True
        return S
    else:
        raise ValueError(strategy.alloc)
    return AL.allocate_topk(score, cand, mandatory, k)


def allocate(strategy: Strategy, state: FedState, task: MMTask,
             fleet: FleetConfig, fed: FedConfig,
             group_flops: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """-> (S [N, G] bool selection, k [N] budgets)."""
    plan = plan_allocation(strategy, task, fleet, fed, group_flops)
    return allocate_rows(plan, strategy, state, np.arange(fleet.N)), plan.k


# ---------------------------------------------------------------------------
# personalization helpers
# ---------------------------------------------------------------------------


def _personal_leaf_mask(proto: Any, strategy: Strategy) -> Any:
    """pytree of bool: True where the leaf stays local (never aggregated).

    ``proto`` is the run's trainable prototype — passed explicitly (runs
    carry it as an attribute) rather than via the old ``id(task)``-keyed
    global cache, whose ids could dangle once tasks were garbage-collected.
    """
    def is_personal(p: str) -> bool:
        if strategy.share_only:
            return not any(s in p for s in strategy.share_only)
        return any(s in p for s in strategy.personal)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        jax.tree.map(lambda x: 0, proto))
    return jax.tree_util.tree_unflatten(
        treedef, [is_personal(mdlora.path_str(p)) for p, _ in leaves])


def _clusters(fleet: FleetConfig) -> np.ndarray:
    """[N] cluster id by identical modality sets (FedLEASE-like)."""
    keys = [tuple(row) for row in fleet.modality_mask.astype(int)]
    uniq = {k: i for i, k in enumerate(dict.fromkeys(keys))}
    return np.array([uniq[k] for k in keys], np.int32)


def _rank_gates(proto: Any, strategy: Strategy, fleet: FleetConfig) -> Any:
    """HeLoRA: [N]-stacked multiplicative masks zeroing LoRA rank tails."""
    N = fleet.N
    if not strategy.rank_caps:
        return jax.tree.map(lambda x: jnp.ones((N,) + x.shape, x.dtype), proto)
    tiers = np.searchsorted([0.5, 2.5], np.argsort(np.argsort(-fleet.tops)))
    # tier by compute rank: top third full rank etc. — use tops quantiles
    q = np.quantile(fleet.tops, [0.34, 0.67])
    tier = np.digitize(-fleet.tops, [-q[1], -q[0]])  # 0=fast..2=slow
    caps = np.array(strategy.rank_caps)[np.clip(tier, 0, len(strategy.rank_caps) - 1)]

    def mk(path, leaf):
        p = mdlora.path_str(path)
        base = np.ones((N,) + leaf.shape, np.float32)
        if "lora" in p and leaf.ndim >= 2 and (p.endswith("['a']") or p.endswith("['b']")):
            r_axis = leaf.ndim - 1 if p.endswith("['a']") else leaf.ndim - 2
            r = leaf.shape[r_axis]
            for n in range(N):
                rn = max(1, int(caps[n] * r))
                sl = [slice(None)] * (leaf.ndim + 1)
                sl[0] = n
                sl[r_axis + 1] = slice(rn, None)
                base[tuple(sl)] = 0.0
        return jnp.asarray(base)

    return jax.tree_util.tree_map_with_path(mk, proto)


# ---------------------------------------------------------------------------
# the round
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FedRun:
    task: MMTask
    strategy: Strategy
    fleet: FleetConfig
    fed: FedConfig
    state: FedState
    local_update: Any
    rank_gate: Any
    personal_mask: Any
    history: dict
    proto: Any  # trainable prototype (zero-round shapes/dtypes)

    @classmethod
    def create(cls, task: MMTask, trainable0: Any, strategy: Strategy,
               fleet: FleetConfig, fed: FedConfig) -> FedRun:
        G = task.layout.G
        state = FedState(
            round=0, trainable=trainable0,
            client_trainable=jax.tree.map(
                lambda x: jnp.broadcast_to(x, (fleet.N,) + x.shape), trainable0),
            dbar=np.ones(G) * 1e-6, mag_ema=np.ones(G),
            rng=np.random.default_rng(fed.seed))
        lu = make_local_update(task, fed, strategy.prox_mu)
        rank_gate = _rank_gates(trainable0, strategy, fleet)
        pmask = _personal_leaf_mask(trainable0, strategy)
        history = {"round": [], "loss": [], "round_time_s": [],
                   "energy_j": [], "upload_mb": [], "f1": [], "f1_round": [],
                   "divergence": [], "selected_frac": []}
        return cls(task, strategy, fleet, fed, state, lu, rank_gate, pmask,
                   history, trainable0)

    # -- data plumbing --------------------------------------------------------

    def _round_batches(self, dataset) -> dict:
        fed, fleet = self.fed, self.fleet
        steps = fed.local_epochs * fed.steps_per_epoch
        return draw_client_batches(self.state.rng, dataset,
                                   range(fleet.N), steps, fed.batch_size)

    # -- one round ------------------------------------------------------------

    def round(self, dataset) -> dict:
        task, strategy, fleet, fed = (self.task, self.strategy, self.fleet,
                                      self.fed)
        layout, state = task.layout, self.state
        N, G = fleet.N, layout.G

        # --- participation / fault injection
        participating = np.ones(N, bool)
        if fed.participation < 1.0:
            m = max(1, int(fed.participation * N))
            participating[:] = False
            participating[state.rng.choice(N, m, replace=False)] = True
        if fed.dropout_prob > 0:
            participating &= state.rng.random(N) > fed.dropout_prob
            if not participating.any():
                participating[state.rng.integers(N)] = True

        # --- server: allocation (blue)
        S, k = allocate(strategy, state, task, fleet, fed, layout.flops)
        S &= participating[:, None]

        # --- clients: local training (green)
        batches = self._round_batches(dataset)
        start = self._start_trainable()
        gates = jnp.asarray(S, jnp.float32)
        mmasks = jnp.asarray(fleet.modality_mask, jnp.float32)
        deltas, losses = self.local_update(start, batches, mmasks, gates,
                                           self.rank_gate, fed.lr)

        # --- server: aggregation (orange)
        trained = jnp.asarray(S, jnp.float32)
        if strategy.agg == "cohort":
            W = AG.cohort_weights(layout, trained, mmasks)
        elif strategy.agg == "dimension":
            # cohort-style masked means but without Eq. 4's B-weighting
            ones_mm = jnp.ones_like(mmasks)
            W = AG.cohort_weights(layout, trained, ones_mm)
        elif strategy.agg == "helora":
            W = AG.cohort_weights(layout, trained, jnp.ones_like(mmasks))
        else:  # fedavg: every participant averaged into every group
            W = AG.fedavg_weights(N, G, jnp.asarray(participating, jnp.float32))

        if strategy.agg == "helora":
            new_trainable = self._helora_aggregate(deltas, trained)
        else:
            new_trainable = AG.aggregate(layout, state.trainable, deltas, W,
                                         fed.server_lr)
        # personalized leaves are NEVER aggregated into the global model
        new_trainable = jax.tree.map(
            lambda old, new, pers: old if pers else new,
            state.trainable, new_trainable, self.personal_mask)

        # personalized leaves: clients keep (or cluster-mix) their own values
        self._update_personal(start, deltas, participating)

        # --- divergence tracking (Eq. 5-6) on possession cohorts
        cohort = jnp.asarray(layout.accessible(fleet.modality_mask)
                             & participating[:, None] & S, jnp.float32)
        d = np.asarray(DV.group_divergence(layout, deltas, cohort))
        state.dbar = np.asarray(DV.ema_update(state.dbar, d, fed.gamma))
        per_client_norms = np.asarray(jax.vmap(
            lambda t: mdlora.group_norms(layout, t))(deltas))
        denom = np.maximum(np.asarray(S).sum(0), 1)
        mag = (per_client_norms * S).sum(0) / denom
        touched = S.any(0)
        state.mag_ema[touched] = (0.5 * state.mag_ema + 0.5 * mag)[touched]

        # --- system simulation (time / energy / comm)
        examples = fed.local_epochs * fed.steps_per_epoch * fed.batch_size
        if fed.sim_mode == "flop_proportional":
            # the paper's Sec. VI-A3 simulator: per-group cost is the
            # *profiled mean* tau_n (matching Eq. 7's uniform budgeting —
            # Table III: V0/V2/V3 share identical budgets AND speedups), and
            # compute is proportional to the trained groups only.
            k_count = np.asarray(S, np.float64).sum(1)
            trained_fl = k_count * float(np.mean(layout.flops)) * examples * 3.0
            fixed_fl = np.zeros(N)
        else:  # fwd_aware (paper Sec. VII): only the backward is maskable,
            # the full-model forward is a fixed cost, and real per-group
            # FLOPs replace the uniform profile.
            sel_flops = np.asarray(S, np.float64) @ layout.flops
            trained_fl = sel_flops * examples * 2.0
            fixed_fl = np.full(N, task.forward_flops_per_example() * examples)
        upload = (np.asarray(S, np.float64) @ layout.sizes) * 4.0
        cost = T.simulate_round(fleet, participating, trained_fl, fixed_fl,
                                upload, fed.t_overhead, fed.utilization)

        state.trainable = new_trainable
        state.round += 1
        rec = {"round": state.round, "loss": float(jnp.mean(losses)),
               **cost.as_dict(), "selected_frac": float(S.mean()),
               "divergence": d}
        for key in ("round", "loss", "round_time_s", "upload_mb"):
            self.history[key].append(rec[key] if key != "round_time_s"
                                     else rec["round_time_s"])
        self.history["energy_j"].append(rec["fleet_energy_j"])
        self.history["divergence"].append(d)
        self.history["selected_frac"].append(rec["selected_frac"])
        return rec

    # -- helpers ---------------------------------------------------------------

    def _start_trainable(self):
        """Per-client starting point: personalized leaves from client state,
        shared leaves broadcast from the global model."""
        def pick(g, c, pers):
            if pers:
                return c
            return jnp.broadcast_to(g, (self.fleet.N,) + g.shape)
        return jax.tree.map(pick, self.state.trainable,
                            self.state.client_trainable, self.personal_mask)

    def _update_personal(self, start, deltas, participating):
        if not jax.tree.reduce(lambda a, b: a or b, self.personal_mask, False):
            return
        part = jnp.asarray(participating, jnp.float32)
        cluster = _clusters(self.fleet)
        onehot = jnp.asarray(
            (cluster[:, None] == np.unique(cluster)[None, :]), jnp.float32)
        onehot = onehot * part[:, None]
        mix = onehot @ (onehot / jnp.maximum(onehot.sum(0, keepdims=True),
                                             1.0)).T  # [N, N] cluster-mean mix

        def upd(c_old, s, d, pers):
            if not pers:
                return c_old
            new = s.astype(jnp.float32) + d
            if self.strategy.cluster_mix:
                new = jnp.einsum("nk,k...->n...", mix, new)
            else:  # keep own value; non-participants keep previous
                new = jnp.where(part.reshape((-1,) + (1,) * (new.ndim - 1)) > 0,
                                new, c_old.astype(jnp.float32))
            return new.astype(c_old.dtype)

        self.state.client_trainable = jax.tree.map(
            upd, self.state.client_trainable, start, deltas,
            self.personal_mask)

    def _helora_aggregate(self, deltas, trained):
        """Elementwise rank-masked mean for LoRA leaves; group mean others."""
        layout = self.task.layout
        W = AG.cohort_weights(layout, trained,
                              jnp.ones_like(jnp.asarray(
                                  self.fleet.modality_mask, jnp.float32)))
        base = mdlora.weighted_combine(layout, deltas, W)

        def fix(path, agg, d_stack, m_stack):
            p = mdlora.path_str(path)
            if "lora" not in p:
                return agg
            num = jnp.sum(d_stack.astype(jnp.float32) * m_stack, axis=0)
            den = jnp.maximum(jnp.sum(m_stack, axis=0), 1e-9)
            return num / den

        agg = jax.tree_util.tree_map_with_path(fix, base, deltas,
                                               self.rank_gate)
        return jax.tree.map(
            lambda t, d: (t.astype(jnp.float32)
                          + self.fed.server_lr * d).astype(t.dtype),
            self.state.trainable, agg)

    # -- evaluation -------------------------------------------------------------

    def evaluate(self, dataset) -> float:
        xs = np.concatenate(dataset.test_x)
        ys = np.concatenate(dataset.test_y)
        if jax.tree.reduce(lambda a, b: a or b, self.personal_mask, False):
            # personalized strategies: per-client models on local test data
            f1s = []
            start = self._start_trainable()
            for n in range(self.fleet.N):
                tr_n = jax.tree.map(lambda x: x[n], start)
                src = n % len(dataset.test_y)
                f1s.append(self.task.eval_f1(tr_n, dataset.test_x[src],
                                             dataset.test_y[src]))
            return float(np.mean(f1s))
        return self.task.eval_f1(self.state.trainable, xs, ys)

    # -- full loop ---------------------------------------------------------------

    def run(self, dataset, rounds: int | None = None,
            log_every: int = 0) -> dict:
        rounds = rounds or self.fed.rounds
        for r in range(rounds):
            rec = self.round(dataset)
            if (r + 1) % self.fed.eval_every == 0 or r == rounds - 1:
                f1 = self.evaluate(dataset)
                self.history["f1"].append(f1)
                self.history["f1_round"].append(rec["round"])
                if log_every and (r + 1) % log_every == 0:
                    print(f"[{self.strategy.name}] round {rec['round']:4d} "
                          f"loss {rec['loss']:.4f} F1 {f1:.4f} "
                          f"t={rec['round_time_s']:.3f}s")
        return self.history
