"""Event-driven asynchronous federated runtime (RELIEF beyond the barrier).

The synchronous engine (core/engine.py) charges every round to its slowest
device — exactly the straggler coupling the paper identifies as the cost of
system-modality heterogeneity. This runtime removes the barrier: each client
trains continuously against the freshest model it has pulled, completions
arrive on a priority queue of simulated (compute + comm) times
(sim/events.py), and the server applies *buffered, staleness-discounted
cohort aggregation*:

  * a FedBuff-style buffer of size K — the server folds the model forward
    once K completions are queued (K = N + homogeneous fleet degenerates to
    the synchronous engine, the parity anchor in tests/test_async_engine.py);
  * each buffered update is discounted by 1/(1+s)^a where s counts server
    versions elapsed since the client pulled (strategies.AsyncStrategy);
  * aggregation reuses the mdlora.GroupLayout block interface through the
    streaming ``aggregation.CohortAggBuffer``, so rare-modality blocks still
    aggregate only within their possession cohort and an empty cohort
    freezes its block — no interference, no NaNs, no matter which subset of
    the fleet happens to sit in the buffer.

Simulated time and energy come from the same device model as the sync
engine (sim/timing.py), so bench_async.py's wall-clock/energy comparisons
are apples-to-apples.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as AG
from repro.core import mdlora
from repro.core.engine import (FedConfig, _PROTO_CACHE, _rank_gates,
                               allocate, draw_client_batches,
                               make_local_update)
from repro.core.strategies import AsyncStrategy
from repro.core.tasks import MMTask
from repro.sim import FleetConfig
from repro.sim.events import AsyncTrace, EventQueue, completion_times

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AsyncFedConfig(FedConfig):
    """FedConfig + event-runtime knobs. ``rounds`` keeps its meaning as the
    *logical* round budget: the default total work is rounds * N client
    updates, matching the synchronous engine's total local compute."""
    jitter_sigma: float = 0.0  # lognormal compute-time noise (0 = exact)
    total_updates: int | None = None  # overrides rounds * N when set
    agg_impl: str = "xla"  # cohort-agg reduction: "xla" | "pallas"
    agg_interpret: bool = True  # Pallas interpret mode (CPU containers)


@dataclasses.dataclass
class AsyncFedState:
    round: int  # server model version = number of flushes applied
    trainable: Any
    dbar: np.ndarray  # [G] EMA divergence (drives allocation, Eq. 5-6)
    mag_ema: np.ndarray  # [G]
    rng: np.random.Generator
    sim_time: float = 0.0


@dataclasses.dataclass
class _Pending:
    """One in-flight client update, created at dispatch (the delta is a pure
    function of the pulled model + batch draw, so simulation computes it
    eagerly; only its *arrival time* is event-driven)."""
    client: int
    version: int  # server version pulled at dispatch
    delta: Any  # trainable-shaped update
    loss: float
    S_row: np.ndarray  # [G] groups trained
    t_comp: float
    t_comm: float
    upload_bytes: float


@dataclasses.dataclass
class AsyncFedRun:
    task: MMTask
    strategy: AsyncStrategy
    fleet: FleetConfig
    fed: AsyncFedConfig
    state: AsyncFedState
    local_update: Any
    rank_gate: Any
    queue: EventQueue
    buffer: list
    trace: AsyncTrace
    history: dict

    @classmethod
    def create(cls, task: MMTask, trainable0: Any, strategy: AsyncStrategy,
               fleet: FleetConfig, fed: AsyncFedConfig) -> "AsyncFedRun":
        if strategy.personal or strategy.share_only:
            raise ValueError("async runtime keeps one global model; "
                             "personalized strategies are sync-only")
        if strategy.agg not in ("cohort", "fedavg"):
            raise ValueError(f"async runtime supports cohort/fedavg "
                             f"aggregation, not {strategy.agg!r}")
        _PROTO_CACHE[id(task)] = trainable0
        G = task.layout.G
        state = AsyncFedState(
            round=0, trainable=trainable0, dbar=np.ones(G) * 1e-6,
            mag_ema=np.ones(G), rng=np.random.default_rng(fed.seed))
        trace = AsyncTrace()
        trace.init_fleet(fleet.N)
        history = {"flush": [], "loss": [], "sim_time_s": [], "energy_j": [],
                   "upload_mb": [], "staleness_mean": [], "f1": [],
                   "f1_flush": [], "divergence": [], "selected_frac": []}
        return cls(task, strategy, fleet, fed, state,
                   make_local_update(task, fed, strategy.prox_mu),
                   _rank_gates(task, strategy, fleet), EventQueue(), [],
                   trace, history)

    # -- client dispatch ------------------------------------------------------

    def _dispatch(self, clients: np.ndarray, now: float, dataset) -> None:
        """Pull the current model to ``clients``, run their local training
        eagerly, and schedule their completion events."""
        task, fed, fleet = self.task, self.fed, self.fleet
        layout, state = task.layout, self.state
        clients = np.asarray(clients, np.int64)
        K = len(clients)
        if K == 0:
            return

        S_full, _ = allocate(self.strategy, state, task, fleet, fed,
                             layout.flops)
        S = S_full[clients]  # [K, G]

        steps = fed.local_epochs * fed.steps_per_epoch
        batches = draw_client_batches(state.rng, dataset, clients, steps,
                                      fed.batch_size)
        start = jax.tree.map(
            lambda g: jnp.broadcast_to(g, (K,) + g.shape), state.trainable)
        gates = jnp.asarray(S, jnp.float32)
        mmasks = jnp.asarray(fleet.modality_mask[clients], jnp.float32)
        rank_gate = jax.tree.map(lambda x: x[clients], self.rank_gate)
        deltas, losses = self.local_update(start, batches, mmasks, gates,
                                           rank_gate, fed.lr)

        examples = steps * fed.batch_size
        if fed.sim_mode == "flop_proportional":
            k_count = np.asarray(S, np.float64).sum(1)
            trained_fl = k_count * float(np.mean(layout.flops)) * examples * 3.0
            fixed_fl = np.zeros(K)
        else:  # fwd_aware
            trained_fl = (np.asarray(S, np.float64) @ layout.flops
                          ) * examples * 2.0
            fixed_fl = np.full(K, task.forward_flops_per_example() * examples)
        upload = (np.asarray(S, np.float64) @ layout.sizes) * 4.0
        dur, t_comp, t_comm = completion_times(
            fleet, clients, trained_fl, fixed_fl, upload, fed.t_overhead,
            fed.utilization, self.fed.jitter_sigma, state.rng)

        losses_np = np.asarray(losses)
        for i, c in enumerate(clients):
            pend = _Pending(int(c), state.round,
                            jax.tree.map(lambda x: x[i], deltas),
                            float(losses_np[i]), S[i], float(t_comp[i]),
                            float(t_comm[i]), float(upload[i]))
            self.queue.push(now + dur[i], int(c), payload=pend)

    # -- server flush ---------------------------------------------------------

    def _flush(self) -> dict:
        """Fold the buffered cohort into the global model (one server
        version). Buffered entries are stacked in client-id order so a full
        homogeneous buffer reproduces the synchronous stack exactly."""
        task, fleet, fed = self.task, self.fleet, self.fed
        layout, state = task.layout, self.state
        entries = sorted(self.buffer, key=lambda e: e.client)
        self.buffer = []
        K = len(entries)

        deltas = jax.tree.map(lambda *xs: jnp.stack(xs),
                              *[e.delta for e in entries])
        S = np.stack([e.S_row for e in entries])  # [K, G]
        client_ids = np.array([e.client for e in entries])
        staleness = np.array([state.round - e.version for e in entries],
                             np.float64)
        fresh = np.ones(K, bool)
        if self.strategy.max_staleness is not None:
            fresh = staleness <= self.strategy.max_staleness
            S = S * fresh[:, None]
        trained = jnp.asarray(S, jnp.float32)
        mmask = jnp.asarray(fleet.modality_mask[client_ids], jnp.float32)

        a = self.strategy.staleness_exponent
        scale = (None if a == 0.0
                 else AG.staleness_discounts(staleness, a))
        if self.strategy.agg == "cohort":
            W = AG.cohort_weights(layout, trained, mmask, client_scale=scale)
        else:  # fedavg: every (fresh) buffered client into every non-empty
            # group — max_staleness drops apply here too
            ones = jnp.asarray(
                np.tile(layout.sizes[None, :] > 0, (K, 1))
                & fresh[:, None], jnp.float32)
            W = AG.cohort_weights(layout, ones, jnp.ones_like(mmask),
                                  client_scale=scale)

        # divergence cohort: possession AND trained (paper Eq. 5 on the
        # buffered subset)
        acc = layout.accessible(fleet.modality_mask[client_ids])
        C = jnp.asarray(acc & (S > 0), jnp.float32)

        agg = AG.CohortAggBuffer(layout, state.trainable,
                                 impl=fed.agg_impl,
                                 interpret=fed.agg_interpret)
        agg.push(deltas, W, C)
        agg_tree, d, cnt = agg.finalize()

        state.trainable = jax.tree.map(
            lambda t, g: (t.astype(jnp.float32)
                          + fed.server_lr * g).astype(t.dtype),
            state.trainable, agg_tree)

        d_np = np.asarray(d)
        touched = np.asarray(cnt) > 0
        state.dbar[touched] = (fed.gamma * d_np
                               + (1.0 - fed.gamma) * state.dbar)[touched]
        per_client_norms = np.asarray(jax.vmap(
            lambda t: mdlora.group_norms(layout, t))(deltas))
        denom = np.maximum(S.sum(0), 1)
        mag = (per_client_norms * S).sum(0) / denom
        sel = S.any(0)
        state.mag_ema[sel] = (0.5 * state.mag_ema + 0.5 * mag)[sel]

        state.round += 1
        self.trace.flushes += 1
        rec = {"flush": state.round, "sim_time_s": state.sim_time,
               "loss": float(np.mean([e.loss for e in entries])),
               "staleness_mean": float(staleness.mean()),
               "energy_j": self.trace.energy_j,
               "upload_mb": self.trace.upload_mb,
               "selected_frac": float(S.mean()), "divergence": d_np}
        for key in ("flush", "loss", "sim_time_s", "energy_j", "upload_mb",
                    "staleness_mean", "selected_frac", "divergence"):
            self.history[key].append(rec[key])
        return rec

    # -- the event loop -------------------------------------------------------

    def run(self, dataset, total_updates: int | None = None,
            log_every: int = 0) -> dict:
        """Process client completions until ``total_updates`` of them have
        been absorbed (default: rounds * N, the sync engine's total work)."""
        fed, fleet = self.fed, self.fleet
        total = (total_updates or fed.total_updates
                 or fed.rounds * fleet.N)
        K = max(1, min(self.strategy.buffer_size, fleet.N))
        if not len(self.queue):
            self._dispatch(np.arange(fleet.N), self.state.sim_time, dataset)
        processed = 0
        while processed < total and self.queue:
            events = self.queue.pop_simultaneous()
            now = events[0].time
            self.state.sim_time = now
            completed = []
            for ev in events:
                pend: _Pending = ev.payload
                self.buffer.append(pend)
                self.trace.record_completion(fleet, ev.client, pend.t_comp,
                                             pend.t_comm, pend.upload_bytes)
                processed += 1
                completed.append(ev.client)
                if len(self.buffer) >= K:
                    rec = self._flush()
                    if (log_every and rec["flush"] % log_every == 0):
                        print(f"[{self.strategy.name}] flush "
                              f"{rec['flush']:5d} t={rec['sim_time_s']:9.3f}s"
                              f" loss {rec['loss']:.4f} "
                              f"stale {rec['staleness_mean']:.1f}")
                    if (self.fed.eval_every
                            and rec["flush"] % self.fed.eval_every == 0):
                        self.history["f1"].append(self.evaluate(dataset))
                        self.history["f1_flush"].append(rec["flush"])
                if processed >= total:
                    break
            if processed < total:
                self._dispatch(np.array(completed), now, dataset)
        self.trace.sim_time = self.state.sim_time
        if not self.history["f1"]:
            self.history["f1"].append(self.evaluate(dataset))
            self.history["f1_flush"].append(self.state.round)
        return self.history

    # -- evaluation -----------------------------------------------------------

    def evaluate(self, dataset) -> float:
        xs = np.concatenate(dataset.test_x)
        ys = np.concatenate(dataset.test_y)
        return self.task.eval_f1(self.state.trainable, xs, ys)
