"""Event-driven asynchronous federated runtime (RELIEF beyond the barrier).

The synchronous engine (core/engine.py) charges every round to its slowest
device — exactly the straggler coupling the paper identifies as the cost of
system-modality heterogeneity. This runtime removes the barrier: each client
trains continuously against the freshest model it has pulled, completions
arrive on a priority queue of simulated (compute + comm) times
(sim/events.py), and the server applies *buffered, staleness-discounted
cohort aggregation*:

  * a FedBuff-style buffer of size K — the server folds the model forward
    once K completions are queued (K = N + homogeneous fleet degenerates to
    the synchronous engine, the parity anchor in tests/test_async_engine.py);
  * each buffered update is discounted by 1/(1+s)^a where s counts server
    versions elapsed since the client pulled (strategies.AsyncStrategy);
  * aggregation reuses the mdlora.GroupLayout block interface through the
    streaming ``aggregation.CohortAggBuffer``, so rare-modality blocks still
    aggregate only within their possession cohort and an empty cohort
    freezes its block — no interference, no NaNs, no matter which subset of
    the fleet happens to sit in the buffer.

Simulated time and energy come from the same device model as the sync
engine (sim/timing.py), so bench_async.py's wall-clock/energy comparisons
are apples-to-apples.

Two runtimes share one server flush (``_ServerFlushMixin._flush_arrays``):

``AsyncFedRun``           the reference event loop — a heap of per-client
                          ``_Pending`` objects, gradients computed eagerly
                          at dispatch. Exact, but O(N) Python state: fine
                          for N~100, hopeless at fleet scale.
``VectorizedAsyncFedRun`` the structure-of-arrays fleet simulator
                          (sim/fleet.py): all per-client state in flat
                          NumPy arrays, the heap replaced by vectorized
                          next-K extraction with the same FIFO tie-break,
                          and gradient work decoupled from system
                          simulation via ``grad_mode``:

    "dispatch"  gradients at dispatch time for every dispatched client —
                event-for-event equivalent to AsyncFedRun (the history-
                equivalence anchor in tests/test_fleet.py); small fleets.
    "cohort"    system time/energy/staleness simulated for the FULL fleet
                of N clients, but ``local_update`` runs only for the
                M = buffer_size clients actually flushed (M << N), each
                against the retained snapshot of the model version it
                pulled (a bounded ring of ``snapshot_ring`` versions).
                Batch draws are counter-based (seeded by (seed, client,
                completion ticket)), so results are deterministic and
                independent of event interleaving.
    "none"      pure system simulation — no gradients, loss is NaN; this
                is what lets benchmarks/bench_fleet.py sweep N up to 10^6
                and record staleness/energy distributions at fleet scale.

A ``PopulationModel`` (churn_rate / arrival_rate on AsyncFedConfig) adds
arrivals and churn: departing clients lose in-flight work and stop accruing
energy; arrivals rejoin idle and are redispatched on the next event.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import dist
from repro.core import aggregation as AG
from repro.core import mdlora
from repro.core.engine import (AllocPlan, FedConfig, _rank_gates, allocate,
                               allocate_rows, draw_client_batches,
                               make_local_update, plan_allocation,
                               scenario_fed_kwargs)
from repro.core.strategies import AsyncStrategy
from repro.core.tasks import MMTask
from repro.sim import FaultModel, FaultRuntime, FleetConfig
from repro.sim import timing as T
from repro.sim.events import AsyncTrace, EventQueue, completion_times
from repro.sim.fleet import (FleetState, PopulationModel, pack_group_bits,
                             unpack_group_bits)

Array = jax.Array

GRAD_MODES = ("dispatch", "cohort", "none")


@dataclasses.dataclass(frozen=True)
class AsyncFedConfig(FedConfig):
    """FedConfig + event-runtime knobs. ``rounds`` keeps its meaning as the
    *logical* round budget: the default total work is rounds * N client
    updates, matching the synchronous engine's total local compute."""
    jitter_sigma: float = 0.0  # lognormal compute-time noise (0 = exact)
    total_updates: int | None = None  # overrides rounds * N when set
    agg_impl: str = "xla"  # cohort-agg reduction: "xla" | "pallas"
    agg_interpret: bool | None = None  # Pallas interpret (None = auto: CPU)
    # uplink codec: "none" ships fp32 deltas; "int8" quantizes client-side
    # (dist.quantize_int8 + error feedback) and the server ingests the
    # compressed payload natively — dequantization and the staleness
    # discount are fused into the cohort reduction (push_quantized), the
    # fp32 client stack is never rebuilt, and upload bytes drop 4x.
    uplink_codec: str = "none"
    # --- vectorized fleet runtime (VectorizedAsyncFedRun) ---
    grad_mode: str = "dispatch"  # dispatch | cohort | none (see module doc)
    snapshot_ring: int = 8  # retained model versions for cohort gradients
    churn_rate: float = 0.0  # departures per alive client per sim-second
    arrival_rate: float = 0.0  # re-arrivals per departed client per sim-sec
    # fleet fault injection (sim/faults.py): Byzantine delta corruption,
    # mid-round dropout, stalls. None (or byzantine_frac = 0) = fault-free.
    faults: FaultModel | None = None
    # time-varying modality availability (sim/scenarios.StreamingSchedule):
    # when set, each dispatch evaluates the client's LIVE modality mask at
    # the dispatch time — allocation candidates, local-training masks, and
    # the flush's cohort membership all follow it. None = the fleet's
    # static possession mask.
    modality_schedule: Any = None

    @classmethod
    def from_scenario(cls, spec, fleet=None, **overrides):
        """Build the async runtime config a ``sim.scenarios.ScenarioSpec``
        describes (duck-typed). For streaming scenarios the
        ``modality_schedule`` is derived from the spec (pass ``fleet`` to
        reuse an already-built fleet's possession base)."""
        kw = scenario_fed_kwargs(spec) | dict(
            jitter_sigma=spec.jitter_sigma, total_updates=spec.total_updates,
            uplink_codec=spec.uplink_codec, grad_mode=spec.grad_mode,
            faults=spec.faults)
        if (getattr(spec, "missing", None) == "streaming"
                and "modality_schedule" not in overrides):
            from repro.sim.scenarios import schedule_for

            kw["modality_schedule"] = schedule_for(spec, fleet)
        return cls(**(kw | overrides))


@dataclasses.dataclass
class AsyncFedState:
    round: int  # server model version = number of flushes applied
    trainable: Any
    dbar: np.ndarray  # [G] EMA divergence (drives allocation, Eq. 5-6)
    mag_ema: np.ndarray  # [G]
    rng: np.random.Generator
    sim_time: float = 0.0


def _make_state(G: int, trainable0: Any, seed: int) -> AsyncFedState:
    return AsyncFedState(round=0, trainable=trainable0,
                         dbar=np.ones(G) * 1e-6, mag_ema=np.ones(G),
                         rng=np.random.default_rng(seed))


UPLINK_CODECS = ("none", "int8")


def _check_strategy(strategy: AsyncStrategy, fed: AsyncFedConfig,
                    fleet: FleetConfig | None = None) -> None:
    if strategy.personal or strategy.share_only:
        raise ValueError("async runtime keeps one global model; "
                         "personalized strategies are sync-only")
    if strategy.agg not in ("cohort", "fedavg"):
        raise ValueError(f"async runtime supports cohort/fedavg "
                         f"aggregation, not {strategy.agg!r}")
    if fed.uplink_codec not in UPLINK_CODECS:
        raise ValueError(f"uplink_codec must be one of {UPLINK_CODECS}, "
                         f"got {fed.uplink_codec!r}")
    if strategy.robust not in AG.ROBUST_AGGREGATORS:
        raise ValueError(f"robust must be one of {AG.ROBUST_AGGREGATORS}, "
                         f"got {strategy.robust!r}")
    if strategy.selective and not 0.0 < strategy.comm_budget <= 1.0:
        raise ValueError(f"comm_budget must be in (0, 1], "
                         f"got {strategy.comm_budget}")
    sched = fed.modality_schedule
    if sched is not None:
        if strategy.alloc == "random":
            raise ValueError("alloc='random' redraws fleet-shaped noise per "
                             "dispatch; incompatible with a time-varying "
                             "modality schedule")
        if fleet is not None and (sched.N != fleet.N or sched.M != fleet.M):
            raise ValueError(f"modality_schedule shape ({sched.N}, {sched.M})"
                             f" does not match fleet ({fleet.N}, {fleet.M})")


def _make_fault_runtime(fed: AsyncFedConfig,
                        fleet: FleetConfig) -> FaultRuntime | None:
    if fed.faults is not None and fed.faults.active:
        return FaultRuntime(fed.faults, fleet.modality_mask)
    return None


def _selective_upload(layout: mdlora.GroupLayout, deltas: Any,
                      S: np.ndarray, budget: float) -> np.ndarray:
    """FedMFS selective modality communication: which trained blocks to
    upload. Per client, blocks are ranked by Shapley-style utility per byte
    — ||delta_g||^2 / size_g, the marginal-contribution proxy of
    arXiv:2310.07048 — and taken greedily while the cumulative size fits
    ``budget`` x (the client's full trained upload). The top block is always
    taken (an empty upload would stall the protocol); later blocks that
    overflow are skipped, not a hard stop, so the knapsack packs tightly.

    Deterministic in (deltas, S): no rng, stable sort — the heap and
    vectorized runtimes select identical sets for identical dispatches.
    """
    norms = np.asarray(jax.vmap(
        lambda t: mdlora.group_norms(layout, t))(deltas))  # [K, G] squared
    sizes = np.asarray(layout.sizes, np.float64)
    S = np.asarray(S, bool)
    S_up = np.zeros_like(S)
    for k in range(S.shape[0]):
        cand = np.nonzero(S[k])[0]
        if len(cand) == 0:
            continue
        cap = budget * float(sizes[cand].sum())
        density = norms[k, cand] / np.maximum(sizes[cand], 1.0)
        order = cand[np.argsort(-density, kind="stable")]
        spent = 0.0
        for j, g in enumerate(order):
            if j == 0 or spent + sizes[g] <= cap:
                S_up[k, g] = True
                spent += sizes[g]
    return S_up


def _gate_rows(layout: mdlora.GroupLayout, deltas: Any,
               S_up: np.ndarray) -> Any:
    """Zero the non-uploaded blocks of a client-stacked delta pytree."""
    gates = jnp.asarray(S_up, jnp.float32)
    return jax.vmap(lambda t, g: mdlora.group_gate_tree(layout, t, g))(
        deltas, gates)


def _history_init() -> dict:
    return {"flush": [], "loss": [], "sim_time_s": [], "energy_j": [],
            "upload_mb": [], "staleness_mean": [], "f1": [],
            "f1_flush": [], "divergence": [], "selected_frac": []}


@dataclasses.dataclass
class _Pending:
    """One in-flight client update, created at dispatch (the delta is a pure
    function of the pulled model + batch draw, so simulation computes it
    eagerly; only its *arrival time* is event-driven)."""
    client: int
    version: int  # server version pulled at dispatch
    delta: Any  # trainable-shaped update
    loss: float
    S_row: np.ndarray  # [G] groups uploaded (= trained unless selective)
    t_comp: float
    t_comm: float
    upload_bytes: float
    mmask_row: np.ndarray  # [M] live modality mask at dispatch
    # fault-injected mid-round crash: the completion event still fires (it
    # times the client's reboot + redispatch) but is never absorbed — no
    # buffer entry, no energy/upload accounting, no progress
    dropped: bool = False


class _ServerFlushMixin:
    """The server-side flush, shared by both async runtimes.

    Expects ``task/strategy/fleet/fed/state/trace/history/aggbuf``
    attributes on self. ``aggbuf`` is the run-lifetime CohortAggBuffer —
    hoisted out of the per-flush path and reset between flushes, so the
    zero prototypes are derived exactly once per run.
    """

    @property
    def _uplink_bytes_per_param(self) -> float:
        """Simulated uplink cost per shipped parameter (int8 = 1 byte)."""
        return 1.0 if self.fed.uplink_codec == "int8" else 4.0

    def _flush_arrays(self, deltas: Any, S: np.ndarray,
                      client_ids: np.ndarray, losses: np.ndarray | None,
                      staleness: np.ndarray,
                      mmask_rows: np.ndarray | None = None) -> dict:
        """Fold one buffered cohort into the global model (one server
        version). ``deltas``: client-stacked pytree ([K, ...] leaves) or an
        ``aggregation.QuantizedStack`` (int8 uplink — ingested through the
        fused ``push_quantized`` path without rebuilding the fp32 stack),
        rows aligned with ``S``/``client_ids``/``losses``/``staleness`` —
        all sorted by client id so a full homogeneous buffer reproduces the
        synchronous stack exactly. ``deltas=None`` = system-only flush
        (grad_mode "none"): staleness/energy accounting advances, the model
        and divergence state stay untouched, loss records as NaN."""
        task, fleet, fed = self.task, self.fleet, self.fed
        layout, state = task.layout, self.state
        K = len(client_ids)
        quant = isinstance(deltas, AG.QuantizedStack)
        staleness = np.asarray(staleness, np.float64)
        # cohorts are per-flush: under a streaming schedule each buffered
        # update carries the modality mask it was dispatched with, and both
        # the Eq. 3-4 cohort weights and the Eq. 5 divergence cohorts below
        # follow it instead of the fleet's static possession
        if mmask_rows is None:
            mmask_rows = fleet.modality_mask[client_ids]
        fresh = np.ones(K, bool)
        if self.strategy.max_staleness is not None:
            fresh = staleness <= self.strategy.max_staleness
            S = S * fresh[:, None]

        if deltas is not None:
            trained = jnp.asarray(S, jnp.float32)
            mmask = jnp.asarray(mmask_rows, jnp.float32)
            a = self.strategy.staleness_exponent
            scale = (None if a == 0.0
                     else AG.staleness_discounts(staleness, a))
            # quantized ingest applies the discount *inside* the fused
            # reduction, so keep it out of the numerator (defer_scale)
            wkw = dict(client_scale=scale, defer_scale=quant)
            if self.strategy.agg == "cohort":
                W = AG.cohort_weights(layout, trained, mmask, **wkw)
            else:  # fedavg: every (fresh) buffered client into every
                # non-empty group — max_staleness drops apply here too
                ones = jnp.asarray(
                    np.tile(layout.sizes[None, :] > 0, (K, 1))
                    & fresh[:, None], jnp.float32)
                W = AG.cohort_weights(layout, ones, jnp.ones_like(mmask),
                                      **wkw)

            # divergence cohort: possession AND trained (paper Eq. 5 on the
            # buffered subset)
            acc = layout.accessible(mmask_rows)
            C = jnp.asarray(acc & (S > 0), jnp.float32)

            self.aggbuf.reset()
            if quant:
                self.aggbuf.push_quantized(
                    deltas.q, deltas.scales, W, C,
                    jnp.asarray(staleness, jnp.float32), a)
            else:
                self.aggbuf.push(deltas, W, C)
            agg_tree, d, cnt = self.aggbuf.finalize()

            state.trainable = jax.tree.map(
                lambda t, g: (t.astype(jnp.float32)
                              + fed.server_lr * g).astype(t.dtype),
                state.trainable, agg_tree)

            d_np = np.asarray(d)
            touched = np.asarray(cnt) > 0
            state.dbar[touched] = (fed.gamma * d_np
                                   + (1.0 - fed.gamma) * state.dbar)[touched]
            if quant:  # magnitude EMA diagnostic over the K-client buffer
                # (dequantizes [K, ...] for stats only — the hot reduction
                # above never materialized it)
                norm_src = dist.dequantize_int8_stacked(deltas.q,
                                                        deltas.scales)
            else:
                norm_src = deltas
            per_client_norms = np.asarray(jax.vmap(
                lambda t: mdlora.group_norms(layout, t))(norm_src))
            denom = np.maximum(S.sum(0), 1)
            mag = (per_client_norms * S).sum(0) / denom
            sel = S.any(0)
            state.mag_ema[sel] = (0.5 * state.mag_ema + 0.5 * mag)[sel]
            loss = float(np.mean(losses))
        else:  # system-only simulation: no gradient work this flush
            d_np = np.zeros(layout.G)
            loss = float("nan")

        state.round += 1
        self.trace.flushes += 1
        rec = {"flush": state.round, "sim_time_s": state.sim_time,
               "loss": loss, "staleness_mean": float(staleness.mean()),
               "energy_j": self.trace.energy_j,
               "upload_mb": self.trace.upload_mb,
               "selected_frac": float(S.mean()), "divergence": d_np}
        for key in ("flush", "loss", "sim_time_s", "energy_j", "upload_mb",
                    "staleness_mean", "selected_frac", "divergence"):
            self.history[key].append(rec[key])
        return rec

    def _log_and_eval(self, rec: dict, dataset, log_every: int,
                      tag: str) -> None:
        if log_every and rec["flush"] % log_every == 0:
            print(f"[{tag}] flush "
                  f"{rec['flush']:5d} t={rec['sim_time_s']:9.3f}s"
                  f" loss {rec['loss']:.4f} "
                  f"stale {rec['staleness_mean']:.1f}")
        if (self.fed.eval_every and dataset is not None
                and rec["flush"] % self.fed.eval_every == 0):
            self.history["f1"].append(self.evaluate(dataset))
            self.history["f1_flush"].append(rec["flush"])

    # -- evaluation -----------------------------------------------------------

    def evaluate(self, dataset) -> float:
        xs = np.concatenate(dataset.test_x)
        ys = np.concatenate(dataset.test_y)
        return self.task.eval_f1(self.state.trainable, xs, ys)


@dataclasses.dataclass
class AsyncFedRun(_ServerFlushMixin):
    task: MMTask
    strategy: AsyncStrategy
    fleet: FleetConfig
    fed: AsyncFedConfig
    state: AsyncFedState
    local_update: Any
    rank_gate: Any
    queue: EventQueue
    buffer: list
    trace: AsyncTrace
    history: dict
    aggbuf: AG.CohortAggBuffer
    proto: Any  # trainable prototype (explicit, not an id()-keyed cache)
    # client-side error-feedback residuals (uplink_codec="int8"): the
    # quantization error stays on the device and is added to its next
    # update, so the compressed stream telescopes to the uncompressed one
    ef: dict = dataclasses.field(default_factory=dict)
    fx: FaultRuntime | None = None  # fault injection (fed.faults)
    # fleet-static allocation inputs (None for alloc="random", which redraws
    # fleet-shaped noise per dispatch through the legacy allocate() path to
    # preserve its rng stream)
    plan: AllocPlan | None = None

    @classmethod
    def create(cls, task: MMTask, trainable0: Any, strategy: AsyncStrategy,
               fleet: FleetConfig, fed: AsyncFedConfig) -> AsyncFedRun:
        _check_strategy(strategy, fed, fleet)
        state = _make_state(task.layout.G, trainable0, fed.seed)
        trace = AsyncTrace()
        trace.init_fleet(fleet.N)
        aggbuf = AG.CohortAggBuffer(task.layout, trainable0,
                                    impl=fed.agg_impl,
                                    interpret=fed.agg_interpret,
                                    robust=strategy.robust,
                                    trim_frac=strategy.trim_frac,
                                    krum_f=strategy.krum_f)
        plan = (plan_allocation(strategy, task, fleet, fed, task.layout.flops)
                if strategy.alloc != "random" else None)
        return cls(task, strategy, fleet, fed, state,
                   make_local_update(task, fed, strategy.prox_mu),
                   _rank_gates(trainable0, strategy, fleet), EventQueue(),
                   [], trace, _history_init(), aggbuf, trainable0,
                   fx=_make_fault_runtime(fed, fleet), plan=plan)

    # -- client dispatch ------------------------------------------------------

    def _dispatch(self, clients: np.ndarray, now: float, dataset) -> None:
        """Pull the current model to ``clients``, run their local training
        eagerly, and schedule their completion events."""
        task, fed, fleet = self.task, self.fed, self.fleet
        layout, state = task.layout, self.state
        clients = np.asarray(clients, np.int64)
        K = len(clients)
        if K == 0:
            return

        sched = fed.modality_schedule
        live_mm = (sched.masks_at(now, clients) if sched is not None
                   else fleet.modality_mask[clients])
        if self.plan is None:  # alloc="random": legacy full-fleet rng draw
            S_full, _ = allocate(self.strategy, state, task, fleet, fed,
                                 layout.flops)
            S = S_full[clients]  # [K, G]
        elif sched is not None:
            # time-varying masks: allocation candidates follow the LIVE
            # accessibility at dispatch time (budgets stay plan-static)
            unaware = self.strategy.alloc in ("full", "magnitude", "depth")
            S = allocate_rows(
                self.plan, self.strategy, state, clients,
                cand=None if unaware else layout.accessible(live_mm),
                mandatory=(layout.mandatory(live_mm)
                           if self.strategy.mandatory else None))
        else:
            S = allocate_rows(self.plan, self.strategy, state, clients)
        fault = (self.fx.on_dispatch(clients)
                 if self.fx is not None else None)

        steps = fed.local_epochs * fed.steps_per_epoch
        batches = draw_client_batches(state.rng, dataset, clients, steps,
                                      fed.batch_size)
        start = jax.tree.map(
            lambda g: jnp.broadcast_to(g, (K,) + g.shape), state.trainable)
        gates = jnp.asarray(S, jnp.float32)
        mmasks = jnp.asarray(live_mm, jnp.float32)
        rank_gate = jax.tree.map(lambda x: x[clients], self.rank_gate)
        deltas, losses = self.local_update(start, batches, mmasks, gates,
                                           rank_gate, fed.lr)
        if fault is not None:  # corrupt pre-quantization, like a real client
            dropped, slow, byz_rows, tickets = fault
            deltas = self.fx.corrupt(deltas, byz_rows, clients, tickets)
        S_up = S
        if self.strategy.selective:  # FedMFS: shrink the upload, not compute
            S_up = _selective_upload(layout, deltas, S,
                                     self.strategy.comm_budget)
            deltas = _gate_rows(layout, deltas, S_up)

        examples = steps * fed.batch_size
        if fed.sim_mode == "flop_proportional":
            k_count = np.asarray(S, np.float64).sum(1)
            trained_fl = k_count * float(np.mean(layout.flops)) * examples * 3.0
            fixed_fl = np.zeros(K)
        else:  # fwd_aware
            trained_fl = (np.asarray(S, np.float64) @ layout.flops
                          ) * examples * 2.0
            fixed_fl = np.full(K, task.forward_flops_per_example() * examples)
        upload = ((np.asarray(S_up, np.float64) @ layout.sizes)
                  * self._uplink_bytes_per_param)
        dur, t_comp, t_comm = completion_times(
            fleet, clients, trained_fl, fixed_fl, upload, fed.t_overhead,
            fed.utilization, self.fed.jitter_sigma, state.rng)
        if fault is not None:  # stalls stretch compute time (and its energy)
            dur = dur + t_comp * (slow - 1.0)
            t_comp = t_comp * slow

        quantize = fed.uplink_codec == "int8"
        losses_np = np.asarray(losses)
        for i, c in enumerate(clients):
            d_i = jax.tree.map(lambda x, i=i: x[i], deltas)
            if quantize:  # client-side compression, EF residual stays local
                q_i, s_i, resid = dist.quantize_int8_ef(
                    d_i, self.ef.get(int(c)))
                self.ef[int(c)] = resid
                d_i = (q_i, s_i)
            pend = _Pending(int(c), state.round, d_i,
                            float(losses_np[i]), S_up[i], float(t_comp[i]),
                            float(t_comm[i]), float(upload[i]), live_mm[i],
                            dropped=fault is not None and bool(dropped[i]))
            self.queue.push(now + dur[i], int(c), payload=pend)

    # -- server flush ---------------------------------------------------------

    def _flush(self) -> dict:
        """Stack the buffered cohort (client-id order) and fold it into the
        global model through the shared ``_flush_arrays``."""
        entries = sorted(self.buffer, key=lambda e: e.client)
        self.buffer = []
        if self.fed.uplink_codec == "int8":
            deltas = AG.QuantizedStack(
                jax.tree.map(lambda *xs: jnp.stack(xs),
                             *[e.delta[0] for e in entries]),
                jax.tree.map(lambda *xs: jnp.stack(xs),
                             *[e.delta[1] for e in entries]))
        else:
            deltas = jax.tree.map(lambda *xs: jnp.stack(xs),
                                  *[e.delta for e in entries])
        S = np.stack([e.S_row for e in entries])  # [K, G]
        client_ids = np.array([e.client for e in entries])
        staleness = np.array([self.state.round - e.version for e in entries],
                             np.float64)
        losses = np.array([e.loss for e in entries])
        mmask_rows = np.stack([e.mmask_row for e in entries])
        return self._flush_arrays(deltas, S, client_ids, losses, staleness,
                                  mmask_rows=mmask_rows)

    # -- the event loop -------------------------------------------------------

    def run(self, dataset, total_updates: int | None = None,
            log_every: int = 0) -> dict:
        """Process client completions until ``total_updates`` of them have
        been absorbed (default: rounds * N, the sync engine's total work)."""
        fed, fleet = self.fed, self.fleet
        total = (total_updates or fed.total_updates
                 or fed.rounds * fleet.N)
        K = max(1, min(self.strategy.buffer_size, fleet.N))
        if not len(self.queue):
            self._dispatch(np.arange(fleet.N), self.state.sim_time, dataset)
        processed = 0
        while processed < total and self.queue:
            events = self.queue.pop_simultaneous()
            now = events[0].time
            self.state.sim_time = now
            completed = []
            for ev in events:
                pend: _Pending = ev.payload
                completed.append(ev.client)
                if pend.dropped:  # crash: reboot + redispatch, nothing lands
                    continue
                self.buffer.append(pend)
                self.trace.record_completion(fleet, ev.client, pend.t_comp,
                                             pend.t_comm, pend.upload_bytes)
                processed += 1
                if len(self.buffer) >= K:
                    rec = self._flush()
                    self._log_and_eval(rec, dataset, log_every,
                                       self.strategy.name)
                if processed >= total:
                    break
            if processed < total:
                self._dispatch(np.array(completed), now, dataset)
        self.trace.sim_time = self.state.sim_time
        if not self.history["f1"]:
            self.history["f1"].append(self.evaluate(dataset))
            self.history["f1_flush"].append(self.state.round)
        return self.history


# ---------------------------------------------------------------------------
# the vectorized fleet runtime
# ---------------------------------------------------------------------------


class VectorizedAsyncFedRun(_ServerFlushMixin):
    """Structure-of-arrays async runtime for fleet-scale N (sim/fleet.py).

    Same protocol as ``AsyncFedRun`` — FedBuff buffer-K flushes with
    staleness-discounted cohort aggregation — but all per-client system
    state lives in flat arrays, events come from vectorized next-K
    extraction instead of a heap, and gradient computation is decoupled
    from system simulation via ``fed.grad_mode`` (see module docstring).
    With ``grad_mode="dispatch"`` the flush history (loss, staleness,
    selected_frac, sim_time) is event-for-event identical to AsyncFedRun.
    """

    def __init__(self, task: MMTask, strategy: AsyncStrategy,
                 fleet: FleetConfig, fed: AsyncFedConfig,
                 state: AsyncFedState, local_update: Any, plan: AllocPlan,
                 fstate: FleetState, population: PopulationModel | None,
                 trace: AsyncTrace, history: dict,
                 aggbuf: AG.CohortAggBuffer, proto: Any):
        self.task = task
        self.strategy = strategy
        self.fleet = fleet
        self.fed = fed
        self.state = state
        self.local_update = local_update
        self.plan = plan
        self.fstate = fstate
        self.population = population
        self.trace = trace
        self.history = history
        self.aggbuf = aggbuf
        self.proto = proto
        self.grad_mode = fed.grad_mode
        self.ring_clamped = 0  # cohort-mode pulls older than the ring
        # fault injection: drop/stall/corruption flags are drawn at dispatch
        # (counter-based, heap-parity) and consulted at absorb/flush time
        self.fx = _make_fault_runtime(fed, fleet)
        self._drop_next = np.zeros(fleet.N, bool)  # in-flight cycle crashes
        self._fault_ticket = np.zeros(fleet.N, np.int64)  # in-flight ticket
        # buffered (completed, not yet flushed) client state — columnar
        self._buf_client: list[np.ndarray] = []
        self._buf_version: list[np.ndarray] = []
        self._buf_bits: list[np.ndarray] = []
        self._buf_mmbits: list[np.ndarray] = []  # live modality masks
        self._buf_ticket: list[np.ndarray] = []
        self._buf_fticket: list[np.ndarray] = []  # fault tickets (fx only)
        self._buf_loss: list[np.ndarray] = []
        self._buf_deltas: list[Any] = []
        self._buf_scales: list[Any] = []  # uplink_codec="int8" only
        self._buf_count = 0
        # dispatch-mode in-flight gradient store ([N, ...] stacked leaves);
        # with uplink_codec="int8" the leaves are int8 (4x less memory),
        # `_pend_scales` holds the [N] per-leaf dequant scales and `_ef`
        # the fp32 [N, ...] client-side error-feedback residuals
        self._pend_deltas: Any = None
        self._pend_loss: np.ndarray | None = None
        self._pend_scales: Any = None
        self._ef: Any = None
        # cohort-mode ring of the last `snapshot_ring` model versions
        self._ring: Any = None
        if fed.grad_mode == "cohort":
            R = max(1, fed.snapshot_ring)
            self._ring = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (R,) + x.shape), proto)
        self._rank_rows_cache: dict[int, Any] = {}
        self._churn_rng = np.random.default_rng([fed.seed, 0x5EED])

    @classmethod
    def create(cls, task: MMTask, trainable0: Any, strategy: AsyncStrategy,
               fleet: FleetConfig, fed: AsyncFedConfig
               ) -> VectorizedAsyncFedRun:
        _check_strategy(strategy, fed, fleet)
        if fed.grad_mode not in GRAD_MODES:
            raise ValueError(f"grad_mode must be one of {GRAD_MODES}, "
                             f"got {fed.grad_mode!r}")
        if strategy.selective and fed.grad_mode != "dispatch":
            raise ValueError("selective upload ranks the actual deltas at "
                             "dispatch; grad_mode='cohort'/'none' never "
                             "materializes them")
        if strategy.rank_caps:
            raise ValueError("rank_caps build an [N, ...]-stacked gate tree "
                             "— unsupported at fleet scale")
        if strategy.alloc == "random":
            raise ValueError("alloc='random' draws fleet-shaped noise per "
                             "dispatch; use the event-loop AsyncFedRun")
        state = _make_state(task.layout.G, trainable0, fed.seed)
        trace = AsyncTrace()
        trace.init_fleet(fleet.N)
        plan = plan_allocation(strategy, task, fleet, fed, task.layout.flops)
        pop = (PopulationModel(fed.churn_rate, fed.arrival_rate)
               if (fed.churn_rate > 0.0 or fed.arrival_rate > 0.0) else None)
        lu = (make_local_update(task, fed, strategy.prox_mu)
              if fed.grad_mode != "none" else None)
        aggbuf = AG.CohortAggBuffer(task.layout, trainable0,
                                    impl=fed.agg_impl,
                                    interpret=fed.agg_interpret,
                                    robust=strategy.robust,
                                    trim_frac=strategy.trim_frac,
                                    krum_f=strategy.krum_f)
        return cls(task, strategy, fleet, fed, state, lu, plan,
                   FleetState.create(fleet.N), pop, trace, _history_init(),
                   aggbuf, trainable0)

    # -- client dispatch ------------------------------------------------------

    def _rank_gate_rows(self, b: int) -> Any:
        """All-ones per-client gate rows (rank_caps are rejected above)."""
        if b not in self._rank_rows_cache:
            self._rank_rows_cache[b] = jax.tree.map(
                lambda x: jnp.ones((b,) + x.shape, x.dtype), self.proto)
        return self._rank_rows_cache[b]

    def _dispatch_vec(self, idx: np.ndarray, now: float, dataset) -> None:
        """Pull the current model to clients ``idx`` and schedule their
        completions — array-resident, O(batch) given the cached AllocPlan."""
        task, fed, fleet = self.task, self.fed, self.fleet
        layout, state = task.layout, self.state
        idx = np.asarray(idx, np.int64)
        B = len(idx)
        if B == 0:
            return
        sched = fed.modality_schedule
        live_mm = (sched.masks_at(now, idx) if sched is not None
                   else fleet.modality_mask[idx])
        if sched is not None:  # live candidates, plan-static budgets
            unaware = self.strategy.alloc in ("full", "magnitude", "depth")
            S = allocate_rows(
                self.plan, self.strategy, state, idx,
                cand=None if unaware else layout.accessible(live_mm),
                mandatory=(layout.mandatory(live_mm)
                           if self.strategy.mandatory else None))
        else:
            S = allocate_rows(self.plan, self.strategy, state, idx)  # [B, G]
        fault = None
        if self.fx is not None:
            fault = self.fx.on_dispatch(idx)
            self._drop_next[idx] = fault[0]
            self._fault_ticket[idx] = fault[3]

        steps = fed.local_epochs * fed.steps_per_epoch
        S_up = S  # uploaded set (= trained unless selective shrinks it)
        if self.grad_mode == "dispatch":
            batches = draw_client_batches(state.rng, dataset, idx, steps,
                                          fed.batch_size)
            start = jax.tree.map(
                lambda g: jnp.broadcast_to(g, (B,) + g.shape),
                state.trainable)
            gates = jnp.asarray(S, jnp.float32)
            mmasks = jnp.asarray(live_mm, jnp.float32)
            deltas, losses = self.local_update(
                start, batches, mmasks, gates, self._rank_gate_rows(B),
                fed.lr)
            if fault is not None:  # corrupt pre-quantization (heap parity)
                deltas = self.fx.corrupt(deltas, fault[2], idx, fault[3])
            if self.strategy.selective:  # FedMFS: shrink upload, not compute
                S_up = _selective_upload(layout, deltas, S,
                                         self.strategy.comm_budget)
                deltas = _gate_rows(layout, deltas, S_up)
            quantize = fed.uplink_codec == "int8"
            if self._pend_deltas is None:
                store_dtype = jnp.int8 if quantize else jnp.float32
                self._pend_deltas = jax.tree.map(
                    lambda x: jnp.zeros((fleet.N,) + x.shape, store_dtype),
                    self.proto)
                self._pend_loss = np.full(fleet.N, np.nan)
                if quantize:
                    self._pend_scales = jax.tree.map(
                        lambda x: jnp.zeros((fleet.N,), jnp.float32),
                        self.proto)
                    self._ef = jax.tree.map(
                        lambda x: jnp.zeros((fleet.N,) + x.shape,
                                            jnp.float32), self.proto)
            jidx = jnp.asarray(idx)
            if quantize:  # compress client-side, EF residual stays per-row
                q, s, resid = dist.quantize_int8_stacked(
                    deltas, jax.tree.map(lambda r: r[jidx], self._ef))
                self._pend_deltas = jax.tree.map(
                    lambda buf, v: buf.at[jidx].set(v), self._pend_deltas,
                    q)
                self._pend_scales = jax.tree.map(
                    lambda buf, v: buf.at[jidx].set(v), self._pend_scales,
                    s)
                self._ef = jax.tree.map(
                    lambda buf, v: buf.at[jidx].set(v), self._ef, resid)
            else:
                self._pend_deltas = jax.tree.map(
                    lambda buf, d: buf.at[jidx].set(d), self._pend_deltas,
                    deltas)
            self._pend_loss[idx] = np.asarray(losses)

        examples = steps * fed.batch_size
        if fed.sim_mode == "flop_proportional":
            k_count = np.asarray(S, np.float64).sum(1)
            trained_fl = k_count * float(np.mean(layout.flops)) * examples * 3.0
            fixed_fl = np.zeros(B)
        else:  # fwd_aware
            trained_fl = (np.asarray(S, np.float64) @ layout.flops
                          ) * examples * 2.0
            fixed_fl = np.full(B, task.forward_flops_per_example() * examples)
        upload = ((np.asarray(S_up, np.float64) @ layout.sizes)
                  * self._uplink_bytes_per_param)
        dur, t_comp, t_comm = T.cycle_times(
            fleet, idx, trained_fl, fixed_fl, upload, fed.t_overhead,
            fed.utilization, fed.jitter_sigma, state.rng)
        if fault is not None:  # stalls stretch compute time (and energy)
            slow = fault[1]
            dur = dur + t_comp * (slow - 1.0)
            t_comp = t_comp * slow
        self.fstate.dispatch(idx, now, state.round, pack_group_bits(S_up),
                             dur, t_comp, t_comm, upload)
        self.fstate.mod_bits[idx] = pack_group_bits(live_mm)

    # -- completion absorption / flush ----------------------------------------

    def _buf_append(self, chunk: np.ndarray) -> None:
        fs = self.fstate
        self._buf_client.append(chunk.copy())
        self._buf_version.append(fs.version[chunk].copy())
        self._buf_bits.append(fs.group_bits[chunk].copy())
        self._buf_mmbits.append(fs.mod_bits[chunk].copy())
        self._buf_ticket.append(fs.updates[chunk].copy())
        if self.fx is not None:  # cycle's fault ticket, before redispatch
            self._buf_fticket.append(self._fault_ticket[chunk].copy())
        if self.grad_mode == "dispatch":
            self._buf_loss.append(self._pend_loss[chunk].copy())
            jc = jnp.asarray(chunk)
            self._buf_deltas.append(
                jax.tree.map(lambda x: x[jc], self._pend_deltas))
            if self._pend_scales is not None:
                self._buf_scales.append(
                    jax.tree.map(lambda x: x[jc], self._pend_scales))
        self._buf_count += len(chunk)

    def _cohort_update(self, dataset, ids: np.ndarray, versions: np.ndarray,
                       tickets: np.ndarray, S: np.ndarray,
                       mmask_rows: np.ndarray) -> tuple[Any, np.ndarray]:
        """Cohort-sampled gradient computation: local updates for the M
        flushed clients only, each starting from the ring snapshot of the
        version it pulled (pulls older than the ring clamp to the oldest
        retained snapshot; ``ring_clamped`` counts those)."""
        fed, fleet, state = self.fed, self.fleet, self.state
        R = max(1, fed.snapshot_ring)
        vmin = max(0, state.round - R + 1)
        v_eff = np.maximum(versions, vmin)
        self.ring_clamped += int(np.sum(v_eff != versions))
        start = jax.tree.map(lambda x: x[jnp.asarray(v_eff % R)], self._ring)

        steps = fed.local_epochs * fed.steps_per_epoch
        xs, ys = [], []
        for c, t in zip(ids, tickets):  # counter-based draws: order-free
            r = np.random.default_rng([fed.seed, int(c), int(t)])
            src = int(c) % len(dataset.train_y)
            bidx = r.integers(0, len(dataset.train_y[src]),
                              size=(steps, fed.batch_size))
            xs.append(dataset.train_x[src][bidx])
            ys.append(dataset.train_y[src][bidx])
        batches = {"x": jnp.asarray(np.stack(xs)),
                   "y": jnp.asarray(np.stack(ys))}
        gates = jnp.asarray(S, jnp.float32)
        mmasks = jnp.asarray(mmask_rows, jnp.float32)
        deltas, losses = self.local_update(
            start, batches, mmasks, gates, self._rank_gate_rows(len(ids)),
            fed.lr)
        return deltas, np.asarray(losses)

    def _flush_vec(self, dataset) -> dict:
        client = np.concatenate(self._buf_client)
        order = np.argsort(client, kind="stable")  # client-id order (parity)
        ids = client[order]
        versions = np.concatenate(self._buf_version)[order]
        tickets = np.concatenate(self._buf_ticket)[order]
        S = unpack_group_bits(np.concatenate(self._buf_bits)[order],
                              self.task.layout.G)
        mmask_rows = unpack_group_bits(
            np.concatenate(self._buf_mmbits)[order], self.fleet.M)
        staleness = (self.state.round - versions).astype(np.float64)
        quantize = self.fed.uplink_codec == "int8"
        if self.grad_mode == "dispatch":
            losses = np.concatenate(self._buf_loss)[order]
            deltas = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                                  *self._buf_deltas)
            jorder = jnp.asarray(order)
            deltas = jax.tree.map(lambda x: x[jorder], deltas)
            if quantize:  # buffered rows are already the int8 uplink
                scales = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                                      *self._buf_scales)
                deltas = AG.QuantizedStack(
                    deltas, jax.tree.map(lambda x: x[jorder], scales))
        elif self.grad_mode == "cohort":
            deltas, losses = self._cohort_update(dataset, ids, versions,
                                                 tickets, S, mmask_rows)
            if self.fx is not None:  # corrupt with the *buffered* cycle's
                # fault ticket — the client may already be redispatched
                ftickets = np.concatenate(self._buf_fticket)[order]
                deltas = self.fx.corrupt(deltas, self.fx.byz[ids], ids,
                                         ftickets)
            if quantize:  # cohort-sampled gradients quantize at the edge
                # of the simulated uplink (no EF: each (client, ticket)
                # update is drawn exactly once at flush time)
                qt, sc, _ = dist.quantize_int8_stacked(deltas)
                deltas = AG.QuantizedStack(qt, sc)
        else:
            deltas, losses = None, None
        for buf in (self._buf_client, self._buf_version, self._buf_bits,
                    self._buf_mmbits, self._buf_ticket, self._buf_fticket,
                    self._buf_loss, self._buf_deltas, self._buf_scales):
            buf.clear()
        self._buf_count = 0

        rec = self._flush_arrays(deltas, S, ids, losses, staleness,
                                 mmask_rows=mmask_rows)
        if self.grad_mode == "cohort":  # retain the new version's snapshot
            R = max(1, self.fed.snapshot_ring)
            slot = self.state.round % R
            self._ring = jax.tree.map(
                lambda ring, t: ring.at[slot].set(t.astype(ring.dtype)),
                self._ring, self.state.trainable)
        return rec

    def _absorb(self, gidx: np.ndarray, dataset, K: int,
                log_every: int) -> None:
        """Absorb one timestamp group of completions: energy accounting,
        buffer append, flushes at every K-th entry — chunked so trace state
        at each flush matches the one-event-at-a-time loop."""
        fleet, fs = self.fleet, self.fstate
        pos = 0
        while pos < len(gidx):
            room = K - self._buf_count
            chunk = gidx[pos:pos + room]
            pos += len(chunk)
            fs.complete(fleet, chunk)
            self.trace.record_completions(fleet, chunk, fs.t_comp[chunk],
                                          fs.t_comm[chunk],
                                          fs.upload_bytes[chunk])
            self._buf_append(chunk)
            if self._buf_count >= K:
                rec = self._flush_vec(dataset)
                self._log_and_eval(rec, dataset if self.grad_mode != "none"
                                   else None, log_every,
                                   f"vec:{self.strategy.name}")

    # -- the vectorized event loop --------------------------------------------

    def run(self, dataset=None, total_updates: int | None = None,
            log_every: int = 0) -> dict:
        """Absorb ``total_updates`` completions (default rounds * N), with
        vectorized next-K event extraction over the completion-time array.
        ``dataset`` may be None with ``grad_mode="none"``."""
        fed, fleet, state = self.fed, self.fleet, self.state
        if self.grad_mode != "none" and dataset is None:
            raise ValueError(f"grad_mode={self.grad_mode!r} needs a dataset")
        total = (total_updates or fed.total_updates
                 or fed.rounds * fleet.N)
        K = max(1, min(self.strategy.buffer_size, fleet.N))
        fs = self.fstate
        if fs.in_flight == 0:
            self._dispatch_vec(np.nonzero(fs.alive)[0], state.sim_time,
                               dataset)
        processed = 0
        last_t = state.sim_time
        while processed < total and fs.in_flight > 0:
            times, cand = fs.peek_window(K, fed.t_overhead)
            remaining = total - processed
            if self.fx is not None:
                # fault-dropped completions never count toward ``total``:
                # cut the window after the ``remaining``-th *absorbable*
                # event, exactly where the heap loop breaks mid-group —
                # a plain prefix cut would split the redispatch batch and
                # desync the jitter rng stream
                kept_c = np.cumsum(~self._drop_next[cand])
                if len(cand) and kept_c[-1] > remaining:
                    cut = int(np.searchsorted(kept_c, remaining)) + 1
                    times, cand = times[:cut], cand[:cut]
            elif len(cand) > remaining:
                times, cand = times[:remaining], cand[:remaining]
            fs.claim(cand)
            arrivals: list[np.ndarray] = []
            gstart = 0
            while gstart < len(cand):
                t0 = float(times[gstart])
                gend = gstart + int(np.searchsorted(
                    times[gstart:], t0, side="right"))
                gidx = cand[gstart:gend]
                gstart = gend
                state.sim_time = t0
                if self.population is not None:
                    _, arrived = self.population.step(self._churn_rng, fs,
                                                      t0 - last_t)
                    if len(arrived):
                        arrivals.append(arrived)
                    # departures lose their update — even if they re-arrive
                    # before their claimed event's group is processed
                    gidx = gidx[fs.alive[gidx] & ~fs.lost[gidx]]
                last_t = t0
                if len(gidx) == 0:
                    continue
                kept = (gidx[~self._drop_next[gidx]]
                        if self.fx is not None else gidx)
                self._absorb(kept, dataset, K, log_every)
                processed += len(kept)
                if processed >= total:
                    break
                # redispatch everything claimed — a dropped client reboots
                # at the time its completion would have fired
                self._dispatch_vec(gidx, t0, dataset)
            if arrivals and processed < total:
                # genuine re-arrivals from population.step() only — claimed
                # events of this window all have t_next=inf, so an idle-scan
                # would double-dispatch clients whose completion is still
                # pending in a later timestamp group. Dispatched after the
                # window resolves, since dispatch clears ``lost``.
                arr = np.unique(np.concatenate(arrivals))
                self._dispatch_vec(arr[fs.alive[arr]], state.sim_time,
                                   dataset)
        self.trace.sim_time = state.sim_time
        self.trace.per_client_updates = fs.updates.copy()
        if (self.grad_mode != "none" and dataset is not None
                and not self.history["f1"]):
            self.history["f1"].append(self.evaluate(dataset))
            self.history["f1_flush"].append(self.state.round)
        return self.history
