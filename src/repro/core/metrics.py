"""Evaluation metrics (paper VI-A1): macro-F1, per-modality F1 breakdown
(Fig. 6 — model evaluated with only that modality present), rare-modality F1
(avg over the small-cohort modalities), time-to-accuracy."""
from __future__ import annotations

import numpy as np


def confusion(y_true: np.ndarray, y_pred: np.ndarray, n_classes: int) -> np.ndarray:
    cm = np.zeros((n_classes, n_classes), np.int64)
    np.add.at(cm, (y_true, y_pred), 1)
    return cm


def macro_f1(y_true: np.ndarray, y_pred: np.ndarray, n_classes: int) -> float:
    cm = confusion(y_true, y_pred, n_classes)
    tp = np.diag(cm).astype(np.float64)
    fp = cm.sum(0) - tp
    fn = cm.sum(1) - tp
    f1 = 2 * tp / np.maximum(2 * tp + fp + fn, 1e-12)
    present = cm.sum(1) > 0
    return float(f1[present].mean()) if present.any() else 0.0


def evaluate_mm(params, cfg, xs: np.ndarray, ys: np.ndarray,
                modality_mask: np.ndarray, batch: int = 256) -> float:
    """Global-model macro-F1 under a given modality availability mask."""
    import jax.numpy as jnp

    from repro.models.multimodal import mm_forward

    preds = []
    for i in range(0, len(ys), batch):
        logits = mm_forward(params, cfg, jnp.asarray(xs[i:i + batch]),
                            jnp.asarray(modality_mask, jnp.float32))
        preds.append(np.argmax(np.asarray(logits), -1))
    return macro_f1(ys, np.concatenate(preds), cfg.n_classes)


def per_modality_f1(params, cfg, xs, ys, batch: int = 256) -> dict[str, float]:
    """Fig. 6: F1 with only modality m present (others zero-masked)."""
    out = {}
    for i, m in enumerate(cfg.modalities):
        mask = np.zeros((1, cfg.M), np.float32)
        mask[0, i] = 1.0
        out[m.name] = evaluate_mm(params, cfg, xs, ys, mask, batch)
    return out


def rare_modality_f1(per_mod: dict[str, float], rare: tuple[str, ...]) -> float:
    return float(np.mean([per_mod[m] for m in rare]))


def time_to_accuracy(f1_curve: list[float], times: list[float],
                     threshold: float) -> float | None:
    """Wall-clock (simulated) time at which F1 first reaches threshold."""
    for f, t in zip(f1_curve, np.cumsum(times)):
        if f >= threshold:
            return float(t)
    return None
