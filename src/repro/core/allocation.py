"""Divergence-guided elastic allocation (paper Eq. 7, Prop. 4-5).

* ``elastic_budgets``  — Eq. 7: k_n = max(|M_n|, floor((T* - T_o)/tau_n)).
* ``solve_t_star``     — binary search for the smallest per-round time target
  T* such that every device fits its budget within T* (paper IV-B2b: "T* is
  selected via binary search to minimize the maximum per-round time").
* ``allocate_topk``    — top-k_n selection by smoothed divergence with the
  mandatory fusion-block inclusion, vectorized over clients.
* ``water_filling``    — Prop. 4 closed form x_m* = sqrt(D_m)/sum sqrt * K,
  used by tests and by the server's cohort-size targeting diagnostics.
"""
from __future__ import annotations

import numpy as np


def elastic_budgets(tau: np.ndarray, t_star: float, t_overhead: float,
                    n_mandatory: np.ndarray, g_max: np.ndarray) -> np.ndarray:
    """Eq. 7. tau: [N] profiled per-group train time; n_mandatory: [N] |M_n|;
    g_max: [N] number of accessible groups (budget can never exceed it)."""
    raw = np.floor((t_star - t_overhead) / np.maximum(tau, 1e-12)).astype(int)
    return np.clip(np.maximum(n_mandatory, raw), 0, g_max)


def round_time(tau: np.ndarray, k: np.ndarray, t_overhead: float) -> float:
    """Synchronous round = slowest device (straggler)."""
    return float(np.max(t_overhead + tau * k))


def solve_t_star(tau: np.ndarray, t_overhead: float, n_mandatory: np.ndarray,
                 g_max: np.ndarray, target_budget: float | None = None,
                 tol: float = 1e-6) -> float:
    """Binary-search the per-round time target T* (paper IV-B2b).

    Degenerate minimization (T* -> overhead, nobody trains) is excluded by
    the *utilization floor*: the fastest device must complete its full
    accessible set within T* — this matches the paper's measured behavior
    (Fig. 8a: the bottleneck shifts to the fast Type-A device; Table III:
    V0/V2/V3 share identical budgets). Above that floor, the search finds
    the smallest T* consistent with its own induced budgets, or (when
    ``target_budget`` = aggregate K of Prop. 4 is given) the smallest T*
    whose induced aggregate budget reaches K.
    """
    floor = float(np.min(t_overhead + tau * g_max))
    lo = floor
    hi = t_overhead + float(np.max(tau * g_max)) + 1.0

    def feasible(t):
        k = elastic_budgets(tau, t, t_overhead, n_mandatory, g_max)
        if target_budget is not None:
            return k.sum() >= target_budget
        return np.max(np.minimum(t_overhead + tau * k, t_overhead + tau * g_max)
                      ) <= t + tol or t >= floor

    for _ in range(64):
        mid = 0.5 * (lo + hi)
        if feasible(mid):
            hi = mid
        else:
            lo = mid
    return max(hi, floor)


def allocate_topk(dbar: np.ndarray, accessible: np.ndarray,
                  mandatory: np.ndarray, k: np.ndarray,
                  rng: np.random.Generator | None = None,
                  randomize: bool = False) -> np.ndarray:
    """-> S: [N, G] bool selection.

    Per client: include all mandatory groups, then fill the remaining
    k_n - |mandatory| slots with the highest-dbar accessible groups
    (``randomize=True`` replaces the score by noise — ablation V3).

    Fully vectorized over the client axis (a stable argsort ranks each row's
    candidates; non-candidates sink below every candidate), so the
    million-client fleet simulator can allocate a whole dispatch batch in
    one shot. Row-for-row identical to the per-client loop it replaced:
    stable ordering preserves index order among equal scores.
    """
    N, G = accessible.shape
    base = (rng.random(G * N).reshape(N, G) if randomize and rng is not None
            else np.tile(np.asarray(dbar, np.float64), (N, 1)))
    cand = accessible & ~mandatory
    score = np.where(cand, base, -np.inf)
    order = np.argsort(-score, axis=1, kind="stable")  # [N, G]
    rank = np.argsort(order, axis=1, kind="stable")  # rank of each group
    rest = np.maximum(np.asarray(k, np.int64) - mandatory.sum(1), 0)
    return mandatory | (cand & (rank < rest[:, None]))


def water_filling(delta: np.ndarray, K: float) -> tuple[np.ndarray, float]:
    """Prop. 4: minimize sum_m delta_m/x_m s.t. sum_m x_m <= K.

    -> (x*: [M], R*: optimal residual = (sum sqrt(delta))^2 / K).
    """
    sq = np.sqrt(np.maximum(np.asarray(delta, np.float64), 0.0))
    tot = sq.sum()
    if tot == 0 or K <= 0:
        return np.zeros_like(sq), 0.0
    x = sq / tot * K
    return x, float(tot**2 / K)


def weighted_cohort_residual(delta: np.ndarray, x: np.ndarray) -> float:
    """R({x_m}) = sum_m delta_m / x_m (Prop. 4 objective)."""
    x = np.asarray(x, np.float64)
    d = np.asarray(delta, np.float64)
    with np.errstate(divide="ignore"):
        terms = np.where(d > 0, d / np.maximum(x, 1e-300), 0.0)
    return float(terms.sum())
