"""Task adapters binding a model to the federated engine.

``MMTask`` wraps the paper's multimodal model (both backbones):
  * Backbone 1 (cnn):  trainable = ALL parameters; the fusion FC weight is
    the row-blocked leaf.
  * Backbone 2 (transformer): frozen encoders; trainable = LoRA adapters +
    task head; the fusion LoRA ``a`` is the row-blocked leaf.

An adapter exposes: init_trainable, static, loss(trainable, batch), the
GroupLayout, and evaluation helpers. The engine never touches model details.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mdlora
from repro.core import metrics as M
from repro.models import multimodal as MM

Array = jax.Array


def _split_b2(params: dict) -> tuple[dict, dict]:
    """Backbone-2 trainable/static split."""
    trainable = {"lora": params["lora"], "head": params["base"]["head"]}
    static = {k: v for k, v in params["base"].items() if k != "head"}
    return trainable, static


def _merge_b2(trainable: dict, static: dict) -> dict:
    return {"base": dict(static) | {"head": trainable["head"]},
            "lora": trainable["lora"]}


@dataclasses.dataclass
class MMTask:
    cfg: MM.MMConfig
    static: Any
    layout: mdlora.GroupLayout
    _merge: Callable[[Any, Any], dict]

    @classmethod
    def create(cls, cfg: MM.MMConfig, key: Array) -> tuple["MMTask", Any]:
        full_param = cfg.backbone == "cnn"
        params = MM.init_mm_model(key, cfg)
        if full_param:
            # B1 trains everything; keep fusion LoRA out entirely
            params.pop("lora", None)
            trainable, static = params, {}
            merge = lambda t, s: t
        else:
            trainable, static = _split_b2(params)
            merge = _merge_b2
        layout = mdlora.mm_group_layout(cfg, trainable)
        task = cls(cfg, static, layout, merge)
        layout.flops = task.group_compute_flops()  # per-example fwd FLOPs
        return task, trainable

    def params(self, trainable: Any) -> dict:
        return self._merge(trainable, self.static)

    def loss(self, trainable: Any, batch: dict) -> Array:
        p = self.params(trainable)
        logits = MM.mm_forward(p, self.cfg, batch["x"], batch["modality_mask"])
        from repro.models import layers as L
        return L.cross_entropy_logits(logits, batch["y"])

    # -- evaluation ----------------------------------------------------------

    def eval_f1(self, trainable: Any, xs, ys, modality_mask=None) -> float:
        p = self.params(trainable)
        mask = (np.ones((1, self.cfg.M), np.float32)
                if modality_mask is None else modality_mask)
        return M.evaluate_mm(p, self.cfg, xs, ys, mask)

    def eval_per_modality(self, trainable: Any, xs, ys) -> dict[str, float]:
        return M.per_modality_f1(self.params(trainable), self.cfg, xs, ys)

    # -- cost model ------------------------------------------------------------

    def group_compute_flops(self) -> np.ndarray:
        """[G] per-example forward FLOPs attributable to each parameter
        group (conv groups get their spatial reuse, unlike raw param counts).
        This drives tau profiling (Eq. 7), the FLOP-proportional timing of
        Sec. VI-A3 and the forward-aware model of Sec. VII."""
        cfg, layout = self.cfg, self.layout
        fl = np.zeros(layout.G)
        for g, name in enumerate(layout.names):
            if name.startswith("A_"):
                m = next(m for m in cfg.modalities
                         if m.name == name[2:])
                fl[g] = 2.0 * m.d_feat * (cfg.lora_rank if cfg.backbone ==
                                          "transformer" else cfg.d_fused)
            elif name == "B_shared":
                fl[g] = 2.0 * cfg.lora_rank * cfg.d_fused
            elif name.startswith("E_") and cfg.backbone == "cnn":
                label = name.split("_")[-1]
                mname = name[2: -(len(label) + 1)]
                m = next(mm for mm in cfg.modalities if mm.name == mname)
                c1, c2 = cfg.cnn_ch
                if label == "conv1":
                    fl[g] = (cfg.window / 2) * cfg.cnn_kernel * m.channels * c1 * 2
                elif label == "conv2":
                    fl[g] = (cfg.window / 4) * cfg.cnn_kernel * c1 * c2 * 2
                else:  # proj
                    fl[g] = 2.0 * c2 * m.d_feat
            elif name.startswith("E_"):  # transformer encoder LoRA layer
                ntok = cfg.window // cfg.patch
                fl[g] = ntok * (4 * cfg.enc_d**2 + 2 * cfg.enc_d * cfg.enc_ff
                                + 2 * ntok * cfg.enc_d) * 2
            elif name.startswith("H_"):
                fl[g] = 2.0 * (cfg.d_fused * cfg.head_hidden
                               if "w1" in name else
                               cfg.head_hidden * cfg.n_classes)
        return np.maximum(fl, 1.0)

    def forward_flops_per_example(self) -> float:
        """Fixed full-model forward cost (paid regardless of elastic masking
        — zero-padded inputs still traverse every encoder)."""
        return float(self.group_compute_flops().sum())
