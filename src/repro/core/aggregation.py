"""Server-side aggregation rules.

* ``cohort_weights`` + ``aggregate``   — RELIEF (paper Eq. 3-4): each group is
  averaged only over the clients that trained it; the shared fusion
  projection B uses normalized modality-count weighting; the head averages
  over its uploaders. All three rules collapse into one [N, G] weight matrix
  consumed by ``mdlora.weighted_combine`` — on a TPU mesh this is a single
  masked reduce over the client axis.
* ``fedavg_weights``                   — naive FedAvg over all N participants
  (zero-padded deltas included): the paper's interference-prone baseline.
* ``lemma1_decomposition``             — the bias^2/variance/interference
  split of Lemma 1, used by diagnostics and tests.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mdlora

Array = jax.Array


def cohort_weights(layout: mdlora.GroupLayout, trained: Array,
                   modality_mask: Array) -> Array:
    """RELIEF combine weights W: [N, G].

    trained: [N, G] float/bool — which groups each client trained+uploaded
    (the active cohort C~_m^r for fusion blocks / encoders).
    modality_mask: [N, M] — possession, for Eq. 4's w_n = (|M_n|/M)/sum(...).
    Empty cohort => all-zero column (the block stays frozen this round).
    """
    trained = jnp.asarray(trained, jnp.float32)
    M = layout.n_modalities
    mcount = jnp.sum(jnp.asarray(modality_mask, jnp.float32), axis=1)  # [N]
    kinds = np.array(layout.kinds)
    is_b = jnp.asarray(kinds == mdlora.KIND_FUSION_B)  # [G]

    u = jnp.where(is_b[None, :], (mcount / M)[:, None], 1.0)  # [N, G]
    w = trained * u
    denom = jnp.sum(w, axis=0, keepdims=True)  # [1, G]
    return jnp.where(denom > 0, w / jnp.maximum(denom, 1e-12), 0.0)


def fedavg_weights(n_clients: int, G: int, participating: Array | None = None
                   ) -> Array:
    """Naive FedAvg: every participant weighted 1/N for every group."""
    if participating is None:
        participating = jnp.ones((n_clients,), jnp.float32)
    p = jnp.asarray(participating, jnp.float32)
    return jnp.tile((p / jnp.maximum(jnp.sum(p), 1.0))[:, None], (1, G))


def aggregate(layout: mdlora.GroupLayout, global_trainable: Any,
              deltas: Any, W: Array, server_lr: float = 1.0) -> Any:
    """theta^{r+1} = theta^r + server_lr * sum_n W[n,g] * delta_n (Eq. 3)."""
    agg = mdlora.weighted_combine(layout, deltas, W)
    return jax.tree.map(
        lambda t, d: (t.astype(jnp.float32) + server_lr * d).astype(t.dtype),
        global_trainable, agg)


# ---------------------------------------------------------------------------
# Lemma 1 diagnostics
# ---------------------------------------------------------------------------


def lemma1_decomposition(block_deltas: Array, cohort: Array) -> dict:
    """Empirical version of Lemma 1 for one fusion block.

    block_deltas: [N, d, r] per-client updates to one block A_m.
    cohort: [N] bool — C_m (possession).
    Returns the scaling/interference/intra-cohort terms and the exact FedAvg
    error; tests assert error <= sum of bound terms (Eq. 12-13).
    """
    c = jnp.asarray(cohort, jnp.float32)
    N = block_deltas.shape[0]
    nC = jnp.sum(c)
    g_bar = jnp.einsum("n,n...->...", c / jnp.maximum(nC, 1.0), block_deltas)
    g_hat = jnp.mean(block_deltas, axis=0)  # FedAvg over all N
    eps_hat = jnp.einsum("n,n...->...", (1 - c) / jnp.maximum(N - nC, 1.0),
                         block_deltas)
    err = jnp.sum(jnp.square(g_hat - g_bar))
    scaling = (1 - nC / N) ** 2 * jnp.sum(jnp.square(g_bar))
    interference = ((N - nC) / N) ** 2 * jnp.sum(jnp.square(eps_hat))
    intra = jnp.einsum("n,n->", c / jnp.maximum(nC, 1.0),
                       jnp.sum(jnp.square(block_deltas - g_bar),
                               axis=tuple(range(1, block_deltas.ndim))))
    return {"error": err, "scaling": scaling, "interference": interference,
            "intra_cohort": intra,
            "bound": 2 * scaling + 2 * interference + intra / jnp.maximum(nC, 1.0)}
