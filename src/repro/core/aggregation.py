"""Server-side aggregation rules.

* ``cohort_weights`` + ``aggregate``   — RELIEF (paper Eq. 3-4): each group is
  averaged only over the clients that trained it; the shared fusion
  projection B uses normalized modality-count weighting; the head averages
  over its uploaders. All three rules collapse into one [N, G] weight matrix
  consumed by ``mdlora.weighted_combine`` — on a TPU mesh this is a single
  masked reduce over the client axis.
* ``fedavg_weights``                   — naive FedAvg over all N participants
  (zero-padded deltas included): the paper's interference-prone baseline.
* ``lemma1_decomposition``             — the bias^2/variance/interference
  split of Lemma 1, used by diagnostics and tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mdlora

Array = jax.Array


def cohort_weights(layout: mdlora.GroupLayout, trained: Array,
                   modality_mask: Array,
                   client_scale: Array | None = None,
                   defer_scale: bool = False) -> Array:
    """RELIEF combine weights W: [N, G].

    trained: [N, G] float/bool — which groups each client trained+uploaded
    (the active cohort C~_m^r for fusion blocks / encoders).
    modality_mask: [N, M] — possession, for Eq. 4's w_n = (|M_n|/M)/sum(...).
    client_scale: optional [N] multiplicative per-client weight applied
    *inside* the normalization (the async runtime passes its staleness
    discounts here, so a stale update shrinks relative to its cohort).
    defer_scale: keep ``client_scale`` in the denominator but *not* the
    numerator — for consumers that re-apply the per-client factor inside a
    fused reduction (the quantized-ingest kernel computes W * 1/(1+s)^a on
    the fly), so W_deferred * client_scale == W_full up to fp rounding.
    Empty cohort => all-zero column (the block stays frozen this round).
    """
    trained = jnp.asarray(trained, jnp.float32)
    M = layout.n_modalities
    mcount = jnp.sum(jnp.asarray(modality_mask, jnp.float32), axis=1)  # [N]
    kinds = np.array(layout.kinds)
    is_b = jnp.asarray(kinds == mdlora.KIND_FUSION_B)  # [G]

    u = jnp.where(is_b[None, :], (mcount / M)[:, None], 1.0)  # [N, G]
    w = num = trained * u
    if client_scale is not None:
        w = w * jnp.asarray(client_scale, jnp.float32)[:, None]
        if not defer_scale:
            num = w
    denom = jnp.sum(w, axis=0, keepdims=True)  # [1, G]
    return jnp.where(denom > 0, num / jnp.maximum(denom, 1e-12), 0.0)


def staleness_discounts(staleness: Array, exponent: float) -> Array:
    """FedBuff-style polynomial staleness discount 1/(1+s)^a. s is measured
    in server model versions (flushes) since the client pulled."""
    s = jnp.asarray(staleness, jnp.float32)
    return 1.0 / jnp.power(1.0 + s, exponent)


def fedavg_weights(n_clients: int, G: int, participating: Array | None = None
                   ) -> Array:
    """Naive FedAvg: every participant weighted 1/N for every group."""
    if participating is None:
        participating = jnp.ones((n_clients,), jnp.float32)
    p = jnp.asarray(participating, jnp.float32)
    return jnp.tile((p / jnp.maximum(jnp.sum(p), 1.0))[:, None], (1, G))


def aggregate(layout: mdlora.GroupLayout, global_trainable: Any,
              deltas: Any, W: Array, server_lr: float = 1.0) -> Any:
    """theta^{r+1} = theta^r + server_lr * sum_n W[n,g] * delta_n (Eq. 3)."""
    agg = mdlora.weighted_combine(layout, deltas, W)
    return jax.tree.map(
        lambda t, d: (t.astype(jnp.float32) + server_lr * d).astype(t.dtype),
        global_trainable, agg)


# ---------------------------------------------------------------------------
# Byzantine-robust within-cohort reducers
# ---------------------------------------------------------------------------
#
# RELIEF's cohort interface (Eq. 3) makes rare-modality cohorts small by
# construction, so one corrupted client can dominate a whole modality block.
# These reducers replace the weighted mean with bounded-breakdown location
# estimates computed *within each group's cohort* (membership = W > 0, the
# trained+fresh clients of cohort_weights): beta-trimmed weighted mean,
# coordinate-wise median, and blockwise Krum. Divergence statistics (Eq. 5)
# are unchanged — only the aggregate is robustified.

ROBUST_AGGREGATORS = ("mean", "trimmed", "median", "krum")


def trimmed_mean(x: Array, w: Array, trim_frac: float) -> Array:
    """Coordinate-wise beta-trimmed weighted mean along axis 0.

    x: [K, ...] values; w: non-negative weights broadcastable to x — w > 0
    marks cohort membership, its magnitude the combine weight. Per
    coordinate, the t = floor(beta * k) smallest and largest member values
    are discarded (k = member count; t is clamped to (k-1)//2 so at least
    one value survives) and the survivors are averaged with their weights
    renormalized. beta = 0 is exactly the weighted mean ``sum(w x)/sum(w)``
    and beta >= 1/2 degenerates to the median element(s). Empty coordinates
    (k = 0) -> 0.
    """
    x = jnp.asarray(x, jnp.float32)
    w = jnp.broadcast_to(jnp.asarray(w, jnp.float32), x.shape)
    member = w > 0
    k = jnp.sum(member, axis=0)
    t = jnp.minimum(jnp.floor(trim_frac * k),
                    jnp.maximum((k - 1) // 2, 0)).astype(jnp.int32)
    # rank members per coordinate; non-members sort to the top (stable, so
    # ranks 0..k-1 land exactly on the members)
    order = jnp.argsort(jnp.where(member, x, jnp.inf), axis=0, stable=True)
    ranks = jnp.argsort(order, axis=0, stable=True)
    keep = member & (ranks >= t) & (ranks < k - t)
    wk = jnp.where(keep, w, 0.0)
    denom = jnp.sum(wk, axis=0)
    return jnp.where(denom > 0,
                     jnp.sum(wk * x, axis=0) / jnp.maximum(denom, 1e-12),
                     0.0)


def coordinate_median(x: Array, member: Array) -> Array:
    """Coordinate-wise median over member rows along axis 0.

    member: bool mask broadcastable to x. Even member counts average the
    two middle order statistics; empty coordinates -> 0. Breakdown point
    1/2 per coordinate — the strongest of the three rules, at the price of
    ignoring combine weights (every member counts once).
    """
    x = jnp.asarray(x, jnp.float32)
    member = jnp.broadcast_to(jnp.asarray(member, bool), x.shape)
    k = jnp.sum(member, axis=0)
    s = jnp.sort(jnp.where(member, x, jnp.inf), axis=0)
    lo = jnp.take_along_axis(s, jnp.maximum((k - 1) // 2, 0)[None], axis=0)
    hi = jnp.take_along_axis(s, jnp.maximum(k // 2, 0)[None], axis=0)
    return jnp.where(k > 0, 0.5 * (lo + hi)[0], 0.0)


def group_pairwise_sq(layout: mdlora.GroupLayout, deltas: Any) -> Array:
    """Per-group pairwise squared distances: [K, K, G].

    d2[i, j, g] = || delta_i - delta_j ||^2 restricted to group g's
    parameters, accumulated over the layout's three leaf classes (fusion
    row blocks, layer-stacked slices, whole leaves).
    """
    leaves = jax.tree_util.tree_flatten_with_path(deltas)[0]
    K = leaves[0][1].shape[0]
    acc = jnp.zeros((K, K, layout.G), jnp.float32)
    for path, leaf in leaves:
        p = mdlora.path_str(path)
        x = leaf.astype(jnp.float32)
        d = x[:, None] - x[None, :]  # [K, K, ...]
        if p == layout.fusion_a_path:
            rg = layout.row_group_vector(leaf.shape[1])
            per_row = jnp.sum(jnp.square(d), axis=tuple(range(3, d.ndim)))
            onehot = jnp.asarray(rg[:, None] == np.arange(layout.G)[None, :],
                                 jnp.float32)
            acc = acc + jnp.einsum("ijd,dg->ijg", per_row, onehot)
        elif p in layout.leaf_axis0_groups:
            ids = layout.leaf_axis0_groups[p]
            per_l = jnp.sum(jnp.square(d), axis=tuple(range(3, d.ndim)))
            onehot = jnp.asarray(ids[:, None] == np.arange(layout.G)[None, :],
                                 jnp.float32)
            acc = acc + jnp.einsum("ijl,lg->ijg", per_l, onehot)
        elif p in layout.leaf_group:
            g = layout.leaf_group[p]
            acc = acc.at[:, :, g].add(
                jnp.sum(jnp.square(d), axis=tuple(range(2, d.ndim))))
    return acc


def krum_select(d2: Array, member: Array, f: int) -> Array:
    """Blockwise Krum selection (Blanchard et al., NeurIPS'17).

    d2: [K, K, G] per-group pairwise squared distances; member: [K, G]
    cohort membership. Per group, score_i = sum of the distances to i's
    k - f - 2 nearest co-members (clamped to >= 1 neighbor) and the
    lowest-scoring member is selected -> [G] int32 selected client row
    (0 for empty groups — mask with ``member.any(0)``).
    """
    member = jnp.asarray(member, bool)
    K = member.shape[0]
    k = jnp.sum(member, axis=0)  # [G]
    pair = (member[:, None, :] & member[None, :, :]
            & ~jnp.eye(K, dtype=bool)[:, :, None])
    ds = jnp.sort(jnp.where(pair, d2, jnp.inf), axis=1)  # [K, K, G]
    csum = jnp.cumsum(jnp.where(jnp.isfinite(ds), ds, 0.0), axis=1)
    nn = jnp.clip(k - f - 2, 1, jnp.maximum(k - 1, 1))  # [G]
    idx = jnp.broadcast_to((nn - 1)[None, None, :], (K, 1, member.shape[1]))
    score = jnp.take_along_axis(csum, idx, axis=1)[:, 0, :]  # [K, G]
    return jnp.argmin(jnp.where(member, score, jnp.inf), axis=0)


def robust_combine(layout: mdlora.GroupLayout, deltas: Any, W: Array,
                   kind: str, trim_frac: float = 0.1,
                   krum_f: int = 1) -> Any:
    """Robust replacement for ``weighted_combine``: per-group location
    estimates of the member deltas (membership = W > 0).

    Same output scale as the Eq. 3 weighted mean (cohort_weights columns
    sum to 1), so ``aggregate`` / the server flush consume it unchanged.
    ``kind="mean"`` falls through to ``weighted_combine``; "krum" takes the
    selected member's block verbatim via a one-hot weight matrix.
    """
    if kind not in ROBUST_AGGREGATORS:
        raise ValueError(f"robust kind must be one of {ROBUST_AGGREGATORS}, "
                         f"got {kind!r}")
    W = jnp.asarray(W, jnp.float32)
    if kind == "mean":
        return mdlora.weighted_combine(layout, deltas, W)
    if kind == "krum":
        d2 = group_pairwise_sq(layout, deltas)
        sel = krum_select(d2, W > 0, krum_f)
        nonempty = jnp.any(W > 0, axis=0)
        W_sel = jnp.zeros_like(W).at[sel, jnp.arange(W.shape[1])].set(
            nonempty.astype(jnp.float32))
        return mdlora.weighted_combine(layout, deltas, W_sel)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(deltas)
    out = []
    for path, leaf in leaves:
        p = mdlora.path_str(path)
        x = leaf.astype(jnp.float32)
        if p == layout.fusion_a_path:
            w = W[:, jnp.asarray(layout.row_group_vector(leaf.shape[1]))]
            w = w.reshape(w.shape + (1,) * (x.ndim - 2))
        elif p in layout.leaf_axis0_groups:
            w = W[:, jnp.asarray(layout.leaf_axis0_groups[p])]
            w = w.reshape(w.shape + (1,) * (x.ndim - 2))
        elif p in layout.leaf_group:
            w = W[:, layout.leaf_group[p]]
            w = w.reshape(w.shape + (1,) * (x.ndim - 1))
        else:
            out.append(jnp.zeros(leaf.shape[1:], jnp.float32))
            continue
        if kind == "trimmed":
            out.append(trimmed_mean(x, w, trim_frac))
        else:  # median
            out.append(coordinate_median(x, w > 0))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# streaming cohort aggregation (async runtime / fleet-scale server)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QuantizedStack:
    """A client-stacked int8 uplink payload: ``q`` leaves are [K, ...] int8
    and ``scales`` leaves are the matching [K] per-(client, leaf) dequant
    scales, as produced by ``dist.quantize_int8_stacked``. The server flush
    paths ingest this natively through ``CohortAggBuffer.push_quantized`` —
    the fp32 client stack is never rebuilt in HBM."""
    q: Any
    scales: Any

    @property
    def n_clients(self) -> int:
        return jax.tree.leaves(self.q)[0].shape[0]


class CohortAggBuffer:
    """Streaming/accumulating variant of the fused cohort-agg reduction.

    The synchronous engine materializes the full [N, ...] delta stack and
    reduces it in one shot; the async runtime receives *partial buffers*
    (FedBuff cohorts of K clients) and at fleet scale even a sync server
    would stream arrivals. This class accumulates Eq. 3 aggregates and the
    Eq. 5 divergence sufficient statistics chunk by chunk:

        push(deltas [K,...], W [K,G], C [K,G])            fp32 uplink
        push_quantized(q, scales, W, C, staleness, a)     int8 uplink
        finalize() -> (agg tree, divergence [G], cohort counts [G])

    The row-blocked fusion leaf goes through ``kernels/cohort_agg`` —
    ``impl="pallas"`` runs the fused Pallas kernel (interpret-mode on CPU —
    auto-detected when ``interpret`` is None), ``impl="xla"`` its einsum
    oracle; both produce the aggregate and the per-row (sqsum, mean, count)
    stats in one pass over the chunk. ``bd=None`` autotunes the kernel block
    size per shape; explicit values snap to the largest divisor of D, so
    blocking survives non-divisible row dimensions. All other leaves use the
    same masked einsum reductions as ``weighted_combine``. Empty cohorts
    finalize to zero aggregate and zero divergence (frozen block), never
    NaN.

    ``robust`` selects the within-cohort location estimate for the
    *aggregate* ("mean" | "trimmed" | "median" | "krum"); divergence stats
    are always the plain Eq. 5 sufficient statistics. Order statistics do
    not stream — robust modes require exactly one ``push`` per finalize
    (the async runtime flushes whole FedBuff cohorts, so this holds there
    by construction) and a second chunked push raises.
    """

    def __init__(self, layout: mdlora.GroupLayout, proto: Any,
                 impl: str = "xla", interpret: bool | None = None,
                 bd: int | None = None, robust: str = "mean",
                 trim_frac: float = 0.1, krum_f: int = 1):
        if robust not in ROBUST_AGGREGATORS:
            raise ValueError(f"robust must be one of {ROBUST_AGGREGATORS}, "
                             f"got {robust!r}")
        self.layout = layout
        self.impl = impl
        self.interpret = interpret
        self.bd = bd
        self.robust = robust
        self.trim_frac = trim_frac
        self.krum_f = krum_f
        # zero prototypes are derived once; reset() re-points the
        # accumulators at them (jnp arrays are immutable, sharing is safe),
        # so a long-lived buffer serves many flushes without re-allocating
        self._zero_tree = jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), proto)
        self._zero_g = jnp.zeros((layout.G,), jnp.float32)
        self.reset()

    def reset(self) -> None:
        """Clear accumulated state so the buffer can serve the next flush."""
        self._agg = self._zero_tree
        self._csum = self._zero_tree
        self._sq = self._zero_g
        self._cnt = self._zero_g
        self._pushes = 0

    def _commit(self, treedef, agg_out, csum_out, sq: Array,
                C: Array) -> None:
        agg_tree = jax.tree_util.tree_unflatten(treedef, agg_out)
        csum_tree = jax.tree_util.tree_unflatten(treedef, csum_out)
        self._agg = jax.tree.map(jnp.add, self._agg, agg_tree)
        self._csum = jax.tree.map(jnp.add, self._csum, csum_tree)
        self._sq = self._sq + sq
        self._cnt = self._cnt + jnp.sum(C, axis=0)

    def push(self, deltas: Any, W: Array, C: Array) -> None:
        """deltas: client-stacked pytree ([K, ...] leaves); W/C: [K, G]
        combine weights and divergence-cohort mask for this chunk."""
        from repro.kernels.cohort_agg import cohort_agg_divergence

        layout = self.layout
        if self.robust != "mean":
            if self._pushes > 0:
                raise RuntimeError(
                    f"robust={self.robust!r} aggregation needs the whole "
                    "cohort in one push; chunked pushes are mean-only")
            self._pushes += 1
        W = jnp.asarray(W, jnp.float32)
        C = jnp.asarray(C, jnp.float32)
        leaves, treedef = jax.tree_util.tree_flatten_with_path(deltas)
        agg_out, csum_out = [], []
        sq = jnp.zeros((layout.G,), jnp.float32)
        for path, leaf in leaves:
            p = mdlora.path_str(path)
            x = leaf.astype(jnp.float32)
            if p == layout.fusion_a_path:
                rg_j = jnp.asarray(layout.row_group_vector(leaf.shape[1]))
                agg_a, sq_rows, mean_rows, cnt_rows = cohort_agg_divergence(
                    x, W[:, rg_j], C[:, rg_j], impl=self.impl,
                    interpret=self.interpret, bd=self.bd)
                agg_out.append(agg_a)
                csum_out.append(mean_rows * cnt_rows[:, None])
                sq = sq.at[rg_j].add(sq_rows)
            elif p in layout.leaf_axis0_groups:
                ids = jnp.asarray(layout.leaf_axis0_groups[p])
                agg_out.append(jnp.einsum("nl,nl...->l...", W[:, ids], x))
                csum_out.append(jnp.einsum("nl,nl...->l...", C[:, ids], x))
                per_l = jnp.sum(jnp.square(x),
                                axis=tuple(range(2, x.ndim)))  # [K, L]
                sq = sq.at[ids].add(jnp.sum(per_l * C[:, ids], axis=0))
            elif p in layout.leaf_group:
                g = layout.leaf_group[p]
                agg_out.append(jnp.einsum("n,n...->...", W[:, g], x))
                csum_out.append(jnp.einsum("n,n...->...", C[:, g], x))
                per_n = jnp.sum(jnp.square(x),
                                axis=tuple(range(1, x.ndim)))  # [K]
                sq = sq.at[g].add(jnp.sum(per_n * C[:, g]))
            else:
                agg_out.append(jnp.zeros(leaf.shape[1:], jnp.float32))
                csum_out.append(jnp.zeros(leaf.shape[1:], jnp.float32))
        if self.robust != "mean":
            # divergence stats above stay the plain sufficient statistics;
            # only the aggregate is swapped for the robust estimate
            agg_out = jax.tree_util.tree_flatten(robust_combine(
                layout, deltas, W, self.robust, self.trim_frac,
                self.krum_f))[0]
        self._commit(treedef, agg_out, csum_out, sq, C)

    def push_quantized(self, q: Any, scales: Any, W: Array, C: Array,
                       staleness: Array | None = None,
                       exponent: float = 0.0) -> None:
        """One-pass compressed ingest: int8 client chunks, dequantized and
        staleness-discounted inside the reduction.

        q: client-stacked pytree ([K, ...] int8 leaves); scales: matching
        [K] per-(client, leaf) dequant scales (``dist.quantize_int8_stacked``
        layout). W/C: [K, G] as in ``push`` — W must be built with
        ``cohort_weights(..., defer_scale=True)`` when the staleness
        discount participates in normalization, because the effective weight
        W * 1/(1+staleness)^exponent is applied *here*: on the fly inside
        the fused kernel for the fusion leaf (the fp32 [K, D, r] stack is
        never materialized), folded into the [K, G] einsum weights for
        everything else.
        """
        from repro.kernels.cohort_agg import cohort_agg_divergence_quant
        from repro.kernels.cohort_agg.ref import staleness_discount_ref

        layout = self.layout
        W = jnp.asarray(W, jnp.float32)
        C = jnp.asarray(C, jnp.float32)
        if staleness is None:
            staleness = jnp.zeros((W.shape[0],), jnp.float32)
        staleness = jnp.asarray(staleness, jnp.float32)
        disc = staleness_discount_ref(staleness, exponent)
        if self.robust != "mean":
            # Order statistics cannot be taken over int8 codes with
            # per-client scales, so the fused compressed ingest does not
            # apply: dequantize the chunk and take the fp32 path, folding
            # the staleness discount into the weights up front (the dequant
            # scale f rides along in x, so W*disc*f matches the fused
            # einsum weights exactly). Costs one [K, ...] fp32 stack.
            from repro import dist
            x = dist.dequantize_int8_stacked(q, scales)
            self.push(x, W * disc[:, None], C)
            return
        leaves, treedef = jax.tree_util.tree_flatten_with_path(q)
        scale_leaves = jax.tree.leaves(scales)
        agg_out, csum_out = [], []
        sq = jnp.zeros((layout.G,), jnp.float32)
        for (path, leaf), f in zip(leaves, scale_leaves):
            p = mdlora.path_str(path)
            f = jnp.asarray(f, jnp.float32)  # [K] dequant scales
            if p == layout.fusion_a_path:
                rg_j = jnp.asarray(layout.row_group_vector(leaf.shape[1]))
                agg_a, sq_rows, mean_rows, cnt_rows = (
                    cohort_agg_divergence_quant(
                        leaf, f, W[:, rg_j], C[:, rg_j], staleness, exponent,
                        impl=self.impl, interpret=self.interpret,
                        bd=self.bd))
                agg_out.append(agg_a)
                csum_out.append(mean_rows * cnt_rows[:, None])
                sq = sq.at[rg_j].add(sq_rows)
            elif p in layout.leaf_axis0_groups:
                ids = jnp.asarray(layout.leaf_axis0_groups[p])
                x = leaf.astype(jnp.float32)
                agg_out.append(jnp.einsum("nl,nl...->l...",
                                          W[:, ids] * (disc * f)[:, None],
                                          x))
                csum_out.append(jnp.einsum("nl,nl...->l...",
                                           C[:, ids] * f[:, None], x))
                per_l = jnp.sum(jnp.square(x),
                                axis=tuple(range(2, x.ndim)))  # [K, L]
                sq = sq.at[ids].add(jnp.sum(
                    per_l * C[:, ids] * jnp.square(f)[:, None], axis=0))
            elif p in layout.leaf_group:
                g = layout.leaf_group[p]
                x = leaf.astype(jnp.float32)
                agg_out.append(jnp.einsum("n,n...->...", W[:, g] * disc * f,
                                          x))
                csum_out.append(jnp.einsum("n,n...->...", C[:, g] * f, x))
                per_n = jnp.sum(jnp.square(x),
                                axis=tuple(range(1, x.ndim)))  # [K]
                sq = sq.at[g].add(jnp.sum(per_n * C[:, g] * jnp.square(f)))
            else:
                agg_out.append(jnp.zeros(leaf.shape[1:], jnp.float32))
                csum_out.append(jnp.zeros(leaf.shape[1:], jnp.float32))
        self._commit(treedef, agg_out, csum_out, sq, C)

    def finalize(self) -> tuple[Any, Array, Array]:
        """-> (aggregate tree, per-group divergence [G], cohort counts [G]).

        Divergence uses the sufficient-statistics identity
        E||d - mean||^2 = E||d||^2 - ||mean||^2 over each group's cohort.
        """
        cnt = self._cnt
        inv = 1.0 / jnp.maximum(cnt, 1.0)
        mean_tree = mdlora.group_gate_tree(self.layout, self._csum, inv)
        msq = mdlora.group_norms(self.layout, mean_tree)
        d = jnp.where(cnt > 0, jnp.maximum(self._sq * inv - msq, 0.0), 0.0)
        return self._agg, d, cnt


# ---------------------------------------------------------------------------
# Lemma 1 diagnostics
# ---------------------------------------------------------------------------


def lemma1_decomposition(block_deltas: Array, cohort: Array) -> dict:
    """Empirical version of Lemma 1 for one fusion block.

    block_deltas: [N, d, r] per-client updates to one block A_m.
    cohort: [N] bool — C_m (possession).
    Returns the scaling/interference/intra-cohort terms and the exact FedAvg
    error; tests assert error <= sum of bound terms (Eq. 12-13).
    """
    c = jnp.asarray(cohort, jnp.float32)
    N = block_deltas.shape[0]
    nC = jnp.sum(c)
    g_bar = jnp.einsum("n,n...->...", c / jnp.maximum(nC, 1.0), block_deltas)
    g_hat = jnp.mean(block_deltas, axis=0)  # FedAvg over all N
    eps_hat = jnp.einsum("n,n...->...", (1 - c) / jnp.maximum(N - nC, 1.0),
                         block_deltas)
    err = jnp.sum(jnp.square(g_hat - g_bar))
    scaling = (1 - nC / N) ** 2 * jnp.sum(jnp.square(g_bar))
    interference = ((N - nC) / N) ** 2 * jnp.sum(jnp.square(eps_hat))
    intra = jnp.einsum("n,n->", c / jnp.maximum(nC, 1.0),
                       jnp.sum(jnp.square(block_deltas - g_bar),
                               axis=tuple(range(1, block_deltas.ndim))))
    return {"error": err, "scaling": scaling, "interference": interference,
            "intra_cohort": intra,
            "bound": 2 * scaling + 2 * interference + intra / jnp.maximum(nC, 1.0)}
