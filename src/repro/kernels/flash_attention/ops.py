"""Public attention op with implementation switch.

``flash_attention(..., impl="pallas")`` is the TPU deployment path; the
model code calls this wrapper so the dry-run (CPU) lowers the XLA oracle
while TPU builds get the tiled kernel.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, q_pos, kv_pos, window=None, softcap=None,
                    impl: str = "pallas", interpret: bool = False,
                    bq: int = 512, bt: int = 512):
    if window is None:
        window = np.iinfo(np.int32).max
    if impl == "pallas":
        return flash_attention_pallas(q, k, v, q_pos, kv_pos, window,
                                      softcap, bq=bq, bt=bt,
                                      interpret=interpret)
    return flash_attention_ref(q, k, v, q_pos, kv_pos, window, softcap)
