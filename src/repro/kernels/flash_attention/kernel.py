"""Online-softmax tiled attention (FlashAttention-style) for the GQA layout,
with causal + sliding-window masking, ring-buffer cache positions and
gemma-2 logit soft-capping.

TPU adaptation (vs. the CUDA original): tiles are sized for VMEM (not SMEM),
the contraction feeds the 128x128 MXU by folding the per-KV-group query
heads G into the row dimension of the score matmul ([bq*G, hd] @ [hd, bt]),
and the m/l/acc running state lives in VMEM scratch across the KV-tile grid
steps (the TPU grid is executed sequentially, which replaces the CUDA
thread-block software pipeline).

Grid: (B, K, S/bq, T/bt) — KV tiles innermost.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, window: int,
            softcap: float | None, n_kv: int):
    t_idx = pl.program_id(3)

    @pl.when(t_idx == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0].astype(jnp.float32)  # [bq, G, hd]
    bq, G, hd = q.shape
    k = k_ref[0, :, 0].astype(jnp.float32)  # [bt, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)  # [bt, hd]
    qpos = qpos_ref[...]  # [bq]
    kpos = kpos_ref[...]  # [bt]

    s = jnp.dot(q.reshape(bq * G, hd) * scale, k.T,
                preferred_element_type=jnp.float32)  # [bq*G, bt]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.repeat(qpos, G)  # [bq*G]
    mask = ((qp[:, None] >= kpos[None, :])
            & ((qp[:, None] - kpos[None, :]) < window)
            & (kpos >= 0)[None, :])
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)  # exp(NEG_INF - m) could be exp(0)=1 if row empty
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(t_idx == n_kv - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, :, 0] = out.reshape(bq, G, hd).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, q_pos, kv_pos, window, softcap=None,
                           bq: int = 512, bt: int = 512,
                           interpret: bool = False):
    """q: [B, S, K, G, hd]; k/v: [B, T, K, hd] -> [B, S, K, G, hd]."""
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    bq, bt = min(bq, S), min(bt, T)
    assert S % bq == 0 and T % bt == 0, (S, T, bq, bt)
    window = int(min(int(window), np.iinfo(np.int32).max))
    grid = (B, K, S // bq, T // bt)
    kern = functools.partial(_kernel, scale=1.0 / math.sqrt(hd),
                             window=window, softcap=softcap, n_kv=T // bt)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, G, hd), lambda b, kh, i, j: (b, i, kh, 0, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda b, kh, i, j: (b, j, kh, 0)),
            pl.BlockSpec((1, bt, 1, hd), lambda b, kh, i, j: (b, j, kh, 0)),
            pl.BlockSpec((bq,), lambda b, kh, i, j: (i,)),
            pl.BlockSpec((bt,), lambda b, kh, i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, G, hd),
                               lambda b, kh, i, j: (b, i, kh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq * G,), jnp.float32),
            pltpu.VMEM((bq * G,), jnp.float32),
            pltpu.VMEM((bq * G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_pos, kv_pos)
