"""Oracle: exact attention over the GQA layout used by the model zoo.

q: [B, S, K, G, hd]; k, v: [B, T, K, hd]; q_pos: [S]; kv_pos: [T]
(-1 = empty cache slot); window: int (tokens; GLOBAL = i32 max);
softcap: float | None.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, q_pos, kv_pos, window, softcap=None):
    hd = q.shape[-1]
    s = jnp.einsum("bqkgh,btkh->bqkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    valid = kv_pos >= 0
    causal = q_pos[:, None] >= kv_pos[None, :]
    in_win = (q_pos[:, None] - kv_pos[None, :]) < window
    mask = (causal & in_win & valid[None, :])[None, :, None, None, :]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqkgt,btkh->bqkgh", p,
                      v.astype(jnp.float32)).astype(q.dtype)
