# Pallas TPU kernels for the perf-critical compute layers (DESIGN.md §5):
#   mdlora          fused block-masked LoRA projection (the paper's fusion op)
#   cohort_agg      fused cohort-masked aggregation + divergence (Eq. 3 + 5)
#   flash_attention online-softmax tiled attention (GQA/SWA/softcap variants)
#   ssd             Mamba-2 SSD chunked scan
# Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper
# with impl switch), ref.py (pure-jnp oracle used by the tests).
