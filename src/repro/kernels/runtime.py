"""Backend detection shared by the kernel wrappers.

Every Pallas kernel in this repo has an ``interpret`` switch. Interpret mode
is correct everywhere but orders of magnitude slower than a compiled kernel —
it exists so the CPU-only CI container can exercise the kernel code paths.
The rule is one line: interpret exactly when the active JAX backend has no
Mosaic/Triton lowering (i.e. CPU). Callers pass ``interpret=None`` to get
that default and only override it in tests.
"""
from __future__ import annotations

import functools

import jax


@functools.cache
def default_interpret() -> bool:
    """True iff the active backend needs Pallas interpret mode (CPU)."""
    return jax.default_backend() == "cpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> backend default; explicit bools pass through."""
    return default_interpret() if interpret is None else bool(interpret)
