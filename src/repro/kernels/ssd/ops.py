"""jit'd wrapper for the SSD kernel (impl switch: pallas on TPU, xla ref)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd.kernel import ssd_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret",
                                             "bh"))
def ssd(x, dt, A_log, Bm, Cm, chunk: int = 128, initial_state=None,
        impl: str = "pallas", interpret: bool = False, bh: int = 8):
    if impl == "pallas":
        assert initial_state is None, "kernel path starts from zero state"
        return ssd_pallas(x, dt, A_log, Bm, Cm, chunk=chunk, bh=bh,
                          interpret=interpret)
    from repro.kernels.ssd.ref import ssd_ref
    return ssd_ref(x, dt, A_log, Bm, Cm, chunk, initial_state)
