"""Oracle for the SSD kernel: the chunked block decomposition from
models/ssm.py is itself validated against the sequential recurrence, so the
kernel oracle reuses it directly."""
from __future__ import annotations

from repro.models.ssm import ssd_chunked as _ssd_chunked_jnp


def ssd_ref(x, dt, A_log, Bm, Cm, chunk, initial_state=None):
    return _ssd_chunked_jnp(x, dt, A_log, Bm, Cm, chunk, initial_state,
                            impl="xla")
