"""Mamba-2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The block decomposition (arXiv:2405.21060 §6) maps naturally onto the TPU:
the intra-chunk term is a masked [Q, Q] matmul chain (MXU work), and the
inter-chunk state recurrence is carried in VMEM scratch across sequential
grid steps along the chunk axis — the Pallas/TPU grid executes in order, so
the scratch state register replaces the CUDA kernel's cross-block semaphore
chain (hardware adaptation note in DESIGN.md §3).

Grid: (B, H/bh, S/Q) — chunks innermost; per-step working set
~ Q*(bh*(p+1)+2n) + Q^2 + bh*p*n floats (Q=128, bh=8, p=64, n=128: ~0.6 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, fs_ref, state_ref,
            *, n_chunks: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)  # [Q, bh, p]
    dt = dt_ref[0].astype(jnp.float32)  # [Q, bh]
    alog = alog_ref[...].astype(jnp.float32)  # [bh]
    Bm = b_ref[0].astype(jnp.float32)  # [Q, n]
    Cm = c_ref[0].astype(jnp.float32)  # [Q, n]
    Q = x.shape[0]

    a = -jnp.exp(alog)[None, :] * dt  # [Q, bh] log-decay
    cum = jnp.cumsum(a, axis=0)  # [Q, bh]
    xdt = x * dt[..., None]  # [Q, bh, p]

    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # [Q, Q]
    tril = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
    diff = cum[:, None, :] - cum[None, :, :]  # [Q, Q, bh]
    Lmat = jnp.exp(jnp.where(tril[:, :, None], diff, NEG_INF))
    y_diag = jnp.einsum("qk,qkh,khp->qhp", scores, Lmat, xdt)

    state = state_ref[...]  # [bh, p, n]
    state_out = jnp.exp(cum)  # [Q, bh] decay from chunk start
    y_off = jnp.einsum("qn,hpn,qh->qhp", Cm, state, state_out)
    y_ref[0] = (y_diag + y_off).astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1:, :] - cum)  # [Q, bh]
    chunk_states = jnp.einsum("qn,qh,qhp->hpn", Bm, decay_to_end, xdt)
    new_state = state * jnp.exp(cum[-1])[:, None, None] + chunk_states
    state_ref[...] = new_state

    @pl.when(c_idx == n_chunks - 1)
    def _finish():
        fs_ref[0] = new_state


def ssd_pallas(x, dt, A_log, Bm, Cm, chunk: int = 128, bh: int = 8,
               interpret: bool = False):
    """x: [b, s, h, p]; dt: [b, s, h]; A_log: [h]; Bm/Cm: [b, s, n]
    -> (y [b, s, h, p], final_state [b, h, p, n])."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    bh = min(bh, h)
    assert s % chunk == 0 and h % bh == 0, (s, chunk, h, bh)
    n_chunks = s // chunk
    grid = (b, h // bh, n_chunks)
    kern = functools.partial(_kernel, n_chunks=n_chunks)
    y, fs = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bh, p), lambda i, j, c: (i, c, j, 0)),
            pl.BlockSpec((1, chunk, bh), lambda i, j, c: (i, c, j)),
            pl.BlockSpec((bh,), lambda i, j, c: (j,)),
            pl.BlockSpec((1, chunk, n), lambda i, j, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j, c: (i, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bh, p), lambda i, j, c: (i, c, j, 0)),
            pl.BlockSpec((1, bh, p, n), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bh, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A_log, Bm, Cm)
    return y, fs
