"""Block-size selection for the cohort-agg and mdlora kernels.

The kernel tiles the row dimension D of the fusion leaf into ``bd``-row
blocks; N streams innermost so the four accumulators stay VMEM-resident.
The right ``bd`` balances per-step DMA size against grid overhead and is
shape- and backend-dependent, so instead of the historical hardcoded 256
the wrappers resolve ``bd=None`` here, once per shape (process-cached):

* interpret mode / XLA impl: timing is meaningless (interpret) or unused
  (the einsum oracle ignores ``bd``), so take the largest divisor of D
  within the VMEM accumulator budget — the fewest-launches heuristic.
* compiled Pallas (real TPU/GPU backend): run a bench_roofline.py-style
  sweep over the candidate cells on dummy data and keep the fastest
  (median of ``_SWEEP_REPS`` timed reps after a compile warm-up).

``largest_divisor`` is also the one-stop fix for non-divisible shapes: any
requested ``bd`` is snapped down to the largest divisor of D that does not
exceed it, so blocking never silently degenerates to a single D-row tile.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

# power-of-two cells the sweep considers (snapped to divisors of D)
_CANDIDATE_CAPS = (64, 128, 256, 512)
_SWEEP_REPS = 3
# accumulators are 2*(bd*r + bd) f32 plus the streamed (bd, r) input tile;
# stay well under the ~16 MB/core VMEM so double buffering has headroom
_VMEM_ACC_BUDGET = 4 * 2**20

_CACHE: dict[tuple, int] = {}


def largest_divisor(D: int, cap: int) -> int:
    """Largest divisor of D that is <= cap (>= 1)."""
    b = max(1, min(int(cap), int(D)))
    while D % b:
        b -= 1
    return b


def candidate_bds(D: int, r: int) -> list[int]:
    """Distinct, VMEM-feasible candidate block sizes for row dimension D."""
    cands = set()
    for cap in _CANDIDATE_CAPS:
        bd = largest_divisor(D, cap)
        if 4 * (2 * bd * (r + 1) + bd * r) <= _VMEM_ACC_BUDGET:
            cands.add(bd)
    return sorted(cands) or [1]


def clear_cache() -> None:
    _CACHE.clear()


def select_block_size(shape: tuple[int, int, int], impl: str = "pallas",
                      interpret: bool = True, quant: bool = False) -> int:
    """Resolve ``bd`` for a [N, D, r] reduction (cached per shape/backend)."""
    N, D, r = (int(x) for x in shape)
    key = (N, D, r, impl, bool(interpret), bool(quant),
           jax.default_backend())
    if key not in _CACHE:
        cands = candidate_bds(D, r)
        if impl != "pallas" or interpret or len(cands) == 1:
            _CACHE[key] = cands[-1]
        else:
            _CACHE[key] = _timed_select(N, D, r, cands, quant)
    return _CACHE[key]


def _timed_select(N: int, D: int, r: int, cands: list[int],
                  quant: bool) -> int:
    from repro.kernels.cohort_agg.kernel import (
        cohort_agg_divergence_pallas, cohort_agg_divergence_quant_pallas)

    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.random((N, D)), jnp.float32)
    C = jnp.asarray(rng.random((N, D)) < 0.5, jnp.float32)
    if quant:
        q = jnp.asarray(rng.integers(-127, 128, (N, D, r)), jnp.int8)
        s = jnp.asarray(rng.random((N,)) * 1e-2, jnp.float32)
        t = jnp.asarray(rng.integers(0, 4, (N,)), jnp.float32)

        def run(bd):
            return cohort_agg_divergence_quant_pallas(
                q, s, W, C, t, 0.5, bd=bd, interpret=False)
    else:
        deltas = jnp.asarray(rng.normal(size=(N, D, r)), jnp.float32)

        def run(bd):
            return cohort_agg_divergence_pallas(deltas, W, C, bd=bd,
                                                interpret=False)

    best, best_t = cands[-1], float("inf")
    for bd in cands:
        jax.block_until_ready(run(bd))  # compile warm-up
        ts = []
        for _ in range(_SWEEP_REPS):
            t0 = time.perf_counter()
            jax.block_until_ready(run(bd))
            ts.append(time.perf_counter() - t0)
        med = sorted(ts)[len(ts) // 2]
        if med < best_t:
            best, best_t = bd, med
    return best


# ---------------------------------------------------------------------------
# mdlora (fused block-LoRA projection) block selection
# ---------------------------------------------------------------------------
#
# The projection kernels tile (T, F, D) -> (bt, bf, bd); the gathered
# multi-adapter variant pins bt=1 (each batch row may use a different
# adapter) and tunes (bf, bd) only. Same policy as the cohort-agg selector:
# largest-divisor fewest-launches heuristic on interpret/XLA backends, a
# timed sweep of the VMEM-feasible candidate cells on compiled Pallas.


def _mdlora_vmem_bytes(bt: int, bf: int, bd: int, r: int) -> int:
    # x tile + w0 tile + a tile + b tile + acc/u scratch, fp32
    return 4 * (bt * bd + bd * bf + bd * r + r * bf + bt * (bf + r))


def mdlora_candidates(T: int, D: int, F: int, r: int,
                      multi: bool) -> list[tuple[int, int, int]]:
    """Distinct VMEM-feasible (bt, bf, bd) cells (bt = 1 when ``multi``)."""
    cands = set()
    for cap in _CANDIDATE_CAPS:
        bt = 1 if multi else largest_divisor(T, cap)
        bf, bd = largest_divisor(F, cap), largest_divisor(D, cap)
        if _mdlora_vmem_bytes(bt, bf, bd, r) <= _VMEM_ACC_BUDGET:
            cands.add((bt, bf, bd))
    return sorted(cands) or [(1, largest_divisor(F, 1), largest_divisor(D, 1))]


def select_mdlora_blocks(shape: tuple[int, int, int, int],
                         impl: str = "pallas", interpret: bool = True,
                         multi: bool = False,
                         n_adapters: int = 1) -> tuple[int, int, int]:
    """Resolve (bt, bf, bd) for a [T, D] x [D, F] (rank r) projection."""
    T, D, F, r = (int(x) for x in shape)
    key = ("mdlora", T, D, F, r, impl, bool(interpret), bool(multi),
           int(n_adapters), jax.default_backend())
    if key not in _CACHE:
        cands = mdlora_candidates(T, D, F, r, multi)
        if impl != "pallas" or interpret or len(cands) == 1:
            _CACHE[key] = cands[-1]
        else:
            _CACHE[key] = _timed_select_mdlora(T, D, F, r, cands, multi,
                                               n_adapters)
    return _CACHE[key]


def _timed_select_mdlora(T: int, D: int, F: int, r: int,
                         cands: list[tuple[int, int, int]], multi: bool,
                         n_adapters: int) -> tuple[int, int, int]:
    from repro.kernels.mdlora.kernel import (mdlora_matmul_multi_pallas,
                                             mdlora_matmul_pallas)

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(T, D)), jnp.float32)
    w0 = jnp.asarray(rng.normal(size=(D, F)) * 0.05, jnp.float32)
    if multi:
        A = max(int(n_adapters), 1)
        a = jnp.asarray(rng.normal(size=(A, D, r)) * 0.1, jnp.float32)
        b = jnp.asarray(rng.normal(size=(A, r, F)) * 0.1, jnp.float32)
        idx = jnp.asarray(rng.integers(0, A, T), jnp.int32)
        mask = jnp.asarray(rng.random((T, D)) < 0.8, jnp.float32)

        def run(cell):
            _, bf, bd = cell
            return mdlora_matmul_multi_pallas(x, w0, a, b, idx, mask, 2.0,
                                              bf=bf, bd=bd, interpret=False)
    else:
        a = jnp.asarray(rng.normal(size=(D, r)) * 0.1, jnp.float32)
        b = jnp.asarray(rng.normal(size=(r, F)) * 0.1, jnp.float32)
        mask = jnp.asarray(rng.random(D) < 0.8, jnp.float32)

        def run(cell):
            bt, bf, bd = cell
            return mdlora_matmul_pallas(x, w0, a, b, mask, 2.0, bt=bt,
                                        bf=bf, bd=bd, interpret=False)

    best, best_t = cands[-1], float("inf")
    for cell in cands:
        jax.block_until_ready(run(cell))  # compile warm-up
        ts = []
        for _ in range(_SWEEP_REPS):
            t0 = time.perf_counter()
            jax.block_until_ready(run(cell))
            ts.append(time.perf_counter() - t0)
        med = sorted(ts)[len(ts) // 2]
        if med < best_t:
            best, best_t = cell, med
    return best
