"""jit'd wrapper for the fused cohort aggregation + divergence kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.cohort_agg.kernel import cohort_agg_divergence_pallas
from repro.kernels.cohort_agg.ref import cohort_agg_divergence_ref


@functools.partial(jax.jit, static_argnames=("impl", "interpret", "bd"))
def cohort_agg_divergence(deltas, W, C, impl: str = "xla",
                          interpret: bool = False, bd: int = 256):
    """deltas [N, D, r], W [N, D] (Eq.3/4 weights), C [N, D] (Eq.5 cohort)
    -> (agg [D,r], sqsum [D], mean [D,r], cnt [D])."""
    if impl == "pallas":
        return cohort_agg_divergence_pallas(deltas, W, C, bd=bd,
                                            interpret=interpret)
    return cohort_agg_divergence_ref(deltas, W, C)
