"""jit'd wrappers for the fused cohort aggregation + divergence kernels.

``interpret=None`` resolves to the backend default (interpret only on CPU —
see kernels/runtime.py), so ``impl="pallas"`` is safe everywhere without the
caller knowing the hardware. ``bd=None`` resolves through the autotuner
(kernels/cohort_agg/autotune.py) at trace time; an explicit ``bd`` is
snapped to the largest divisor of D that does not exceed it.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.cohort_agg.autotune import largest_divisor, select_block_size
from repro.kernels.cohort_agg.kernel import (
    cohort_agg_divergence_pallas, cohort_agg_divergence_quant_pallas)
from repro.kernels.cohort_agg.ref import (cohort_agg_divergence_quant_ref,
                                          cohort_agg_divergence_ref)
from repro.kernels.runtime import resolve_interpret


def _resolve_bd(shape, impl: str, interpret: bool, bd: int | None,
                quant: bool) -> int:
    if bd is None:
        return select_block_size(shape, impl=impl, interpret=interpret,
                                 quant=quant)
    return largest_divisor(shape[1], bd)


@functools.partial(jax.jit, static_argnames=("impl", "interpret", "bd"))
def _agg_jit(deltas, W, C, impl, interpret, bd):
    if impl == "pallas":
        return cohort_agg_divergence_pallas(deltas, W, C, bd=bd,
                                            interpret=interpret)
    return cohort_agg_divergence_ref(deltas, W, C)


def cohort_agg_divergence(deltas, W, C, impl: str = "xla",
                          interpret: bool | None = None,
                          bd: int | None = None):
    """deltas [N, D, r], W [N, D] (Eq.3/4 weights), C [N, D] (Eq.5 cohort)
    -> (agg [D,r], sqsum [D], mean [D,r], cnt [D])."""
    interpret = resolve_interpret(interpret)
    bd = _resolve_bd(deltas.shape, impl, interpret, bd, quant=False)
    return _agg_jit(deltas, W, C, impl, interpret, bd)


@functools.partial(jax.jit,
                   static_argnames=("exponent", "impl", "interpret", "bd"))
def _quant_jit(q, scales, W, C, staleness, exponent, impl, interpret, bd):
    if impl == "pallas":
        return cohort_agg_divergence_quant_pallas(q, scales, W, C, staleness,
                                                  exponent, bd=bd,
                                                  interpret=interpret)
    return cohort_agg_divergence_quant_ref(q, scales, W, C, staleness,
                                           exponent)


def cohort_agg_divergence_quant(q, scales, W, C, staleness,
                                exponent: float = 0.0, impl: str = "xla",
                                interpret: bool | None = None,
                                bd: int | None = None):
    """Fused quantized-ingest aggregation: one pass over the int8 uplink.

    q [N, D, r] int8 client chunks, scales [N] per-(client, leaf) dequant
    scales, W/C [N, D], staleness [N] server versions since pull. Equals
    ``cohort_agg_divergence(q * scales, W / (1+staleness)**exponent, C)``
    without ever materializing the fp32 [N, D, r] stack.
    """
    interpret = resolve_interpret(interpret)
    bd = _resolve_bd(q.shape, impl, interpret, bd, quant=True)
    return _quant_jit(q, scales, W, C, staleness, float(exponent), impl,
                      interpret, bd)
