from repro.kernels.cohort_agg.ops import cohort_agg_divergence
