from repro.kernels.cohort_agg.ops import (cohort_agg_divergence,
                                          cohort_agg_divergence_quant)

__all__ = ["cohort_agg_divergence", "cohort_agg_divergence_quant"]
