"""Fused cohort-masked aggregation + divergence statistics (Pallas).

The server-side hot loop at fleet scale is a masked reduction over the client
axis N of the stacked update tensor [N, D, r] — bandwidth-bound. This kernel
streams the client axis through VMEM once, producing the Eq. 3 aggregate and
the Eq. 5 sufficient statistics (sqsum, cohort mean, count) in the same pass,
instead of the three separate reductions the naive implementation issues.

Grid: (D/bd, N) — N innermost so accumulators stay resident in VMEM scratch;
one [bd, r] tile of every client's delta is DMA'd per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(d_ref, w_ref, c_ref, agg_ref, sq_ref, mean_ref, cnt_ref,
            acc_agg, acc_sq, acc_mean, acc_cnt, *, n_clients: int):
    n_idx = pl.program_id(1)

    @pl.when(n_idx == 0)
    def _init():
        acc_agg[...] = jnp.zeros_like(acc_agg)
        acc_sq[...] = jnp.zeros_like(acc_sq)
        acc_mean[...] = jnp.zeros_like(acc_mean)
        acc_cnt[...] = jnp.zeros_like(acc_cnt)

    d = d_ref[0].astype(jnp.float32)  # [bd, r]
    w = w_ref[0].astype(jnp.float32)  # [bd]
    c = c_ref[0].astype(jnp.float32)  # [bd]
    acc_agg[...] += d * w[:, None]
    acc_sq[...] += c * jnp.sum(jnp.square(d), axis=1)
    acc_mean[...] += d * c[:, None]
    acc_cnt[...] += c

    @pl.when(n_idx == n_clients - 1)
    def _finish():
        agg_ref[...] = acc_agg[...]
        sq_ref[...] = acc_sq[...]
        cnt = acc_cnt[...]
        mean_ref[...] = acc_mean[...] / jnp.maximum(cnt, 1.0)[:, None]
        cnt_ref[...] = cnt


def _row_out_specs_scratch(D: int, bd: int, r: int):
    out_specs = [
        pl.BlockSpec((bd, r), lambda d, n: (d, 0)),
        pl.BlockSpec((bd,), lambda d, n: (d,)),
        pl.BlockSpec((bd, r), lambda d, n: (d, 0)),
        pl.BlockSpec((bd,), lambda d, n: (d,)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((D, r), jnp.float32),
        jax.ShapeDtypeStruct((D,), jnp.float32),
        jax.ShapeDtypeStruct((D, r), jnp.float32),
        jax.ShapeDtypeStruct((D,), jnp.float32),
    ]
    scratch = [
        pltpu.VMEM((bd, r), jnp.float32),
        pltpu.VMEM((bd,), jnp.float32),
        pltpu.VMEM((bd, r), jnp.float32),
        pltpu.VMEM((bd,), jnp.float32),
    ]
    return out_specs, out_shape, scratch


def cohort_agg_divergence_pallas(deltas, W, C, bd: int = 256,
                                 interpret: bool = False):
    N, D, r = deltas.shape
    bd = min(bd, D)
    assert D % bd == 0, (D, bd)
    grid = (D // bd, N)
    kernel = functools.partial(_kernel, n_clients=N)
    out_specs, out_shape, scratch = _row_out_specs_scratch(D, bd, r)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bd, r), lambda d, n: (n, d, 0)),
            pl.BlockSpec((1, bd), lambda d, n: (n, d)),
            pl.BlockSpec((1, bd), lambda d, n: (n, d)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(deltas, W, C)


def _quant_kernel(q_ref, s_ref, w_ref, c_ref, t_ref, agg_ref, sq_ref,
                  mean_ref, cnt_ref, acc_agg, acc_sq, acc_mean, acc_cnt,
                  *, n_clients: int, exponent: float):
    """Quantized-ingest variant: the int8 tile is dequantized in VMEM and
    the FedBuff staleness discount folded into the combine weight, in the
    same accumulation — the fp32 client stack never exists in HBM."""
    n_idx = pl.program_id(1)

    @pl.when(n_idx == 0)
    def _init():
        acc_agg[...] = jnp.zeros_like(acc_agg)
        acc_sq[...] = jnp.zeros_like(acc_sq)
        acc_mean[...] = jnp.zeros_like(acc_mean)
        acc_cnt[...] = jnp.zeros_like(acc_cnt)

    d = q_ref[0].astype(jnp.float32) * s_ref[0]  # dequantized [bd, r] tile
    if exponent == 0.0:
        w = w_ref[0]
    else:  # w_eff = W * 1/(1+s)^a, per-client scalar
        w = w_ref[0] * jnp.power(1.0 + t_ref[0], -exponent)
    c = c_ref[0]
    acc_agg[...] += d * w[:, None]
    acc_sq[...] += c * jnp.sum(jnp.square(d), axis=1)
    acc_mean[...] += d * c[:, None]
    acc_cnt[...] += c

    @pl.when(n_idx == n_clients - 1)
    def _finish():
        agg_ref[...] = acc_agg[...]
        sq_ref[...] = acc_sq[...]
        cnt = acc_cnt[...]
        mean_ref[...] = acc_mean[...] / jnp.maximum(cnt, 1.0)[:, None]
        cnt_ref[...] = cnt


def cohort_agg_divergence_quant_pallas(q, scales, W, C, staleness,
                                       exponent: float, bd: int = 256,
                                       interpret: bool = False):
    """q [N, D, r] int8, scales [N] per-(client, leaf) dequant scales,
    W/C [N, D], staleness [N] -> same outputs as the fp32 kernel for
    effective deltas q*scale and effective weights W/(1+staleness)^a."""
    N, D, r = q.shape
    bd = min(bd, D)
    assert D % bd == 0, (D, bd)
    grid = (D // bd, N)
    kernel = functools.partial(_quant_kernel, n_clients=N,
                               exponent=float(exponent))
    out_specs, out_shape, scratch = _row_out_specs_scratch(D, bd, r)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bd, r), lambda d, n: (n, d, 0)),
            pl.BlockSpec((1,), lambda d, n: (n,)),
            pl.BlockSpec((1, bd), lambda d, n: (n, d)),
            pl.BlockSpec((1, bd), lambda d, n: (n, d)),
            pl.BlockSpec((1,), lambda d, n: (n,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, scales.astype(jnp.float32), W, C, staleness.astype(jnp.float32))
