"""Oracle for the fused cohort aggregation + divergence pass.

Inputs
  deltas [N, D, r]  client-stacked updates (fusion ``a``-style leaf; any
                    2-D trailing shape works, r may be 1)
  W      [N, D]     per-(client,row) combine weights (Eq. 3/4 — rows of a
                    block share the cohort weight; B-weighting folds in here)
  C      [N, D]     divergence cohort mask (Eq. 5)
Outputs
  agg    [D, r]     sum_n W[n,d] * deltas[n,d,:]
  sqsum  [D]        sum_n C[n,d] * ||deltas[n,d,:]||^2
  wsum   [D, r]     sum_n (C[n,d]/cnt_d) * deltas[n,d,:]  (cohort mean)
  cnt    [D]        sum_n C[n,d]

Divergence per block m (Eq. 5) is then
  d_m = sum_{d in block} sqsum[d]/cnt[d] - ||wsum[d]||^2.
"""
from __future__ import annotations

import jax.numpy as jnp


def cohort_agg_divergence_ref(deltas, W, C):
    d32 = deltas.astype(jnp.float32)
    W = W.astype(jnp.float32)
    C = C.astype(jnp.float32)
    agg = jnp.einsum("nd,ndr->dr", W, d32)
    sqsum = jnp.einsum("nd,ndr->d", C, jnp.square(d32))
    cnt = jnp.sum(C, axis=0)
    mean = jnp.einsum("nd,ndr->dr", C, d32) / jnp.maximum(cnt, 1.0)[:, None]
    return agg, sqsum, mean, cnt


def staleness_discount_ref(staleness, exponent: float):
    """FedBuff polynomial discount 1/(1+s)^a (a == 0 -> all-ones)."""
    s = jnp.asarray(staleness, jnp.float32)
    if exponent == 0.0:
        return jnp.ones_like(s)
    return jnp.power(1.0 + s, -exponent)


def cohort_agg_divergence_quant_ref(q, scales, W, C, staleness,
                                    exponent: float):
    """Oracle for the fused quantized-ingest pass.

    Mathematically ``cohort_agg_divergence_ref(q * scales, W * disc, C)``
    with disc = 1/(1+staleness)^a — but written with the per-client scalars
    folded into the [N, D] weights so no fp32 [N, D, r] stack is named (XLA
    keeps the int8->f32 convert inside the fused reduction).
    """
    q32 = q.astype(jnp.float32)
    s = scales.astype(jnp.float32)
    c = C.astype(jnp.float32)
    w_eff = W.astype(jnp.float32) * (staleness_discount_ref(staleness,
                                                            exponent)
                                     * s)[:, None]
    agg = jnp.einsum("nd,ndr->dr", w_eff, q32)
    sqsum = jnp.einsum("nd,ndr->d", c * jnp.square(s)[:, None],
                       jnp.square(q32))
    cnt = jnp.sum(c, axis=0)
    mean = (jnp.einsum("nd,ndr->dr", c * s[:, None], q32)
            / jnp.maximum(cnt, 1.0)[:, None])
    return agg, sqsum, mean, cnt


def divergence_from_stats(sqsum, mean, cnt, row_block_ids, n_blocks: int):
    """Reduce row stats to per-block divergences (Eq. 5)."""
    per_row = jnp.where(cnt > 0, sqsum / jnp.maximum(cnt, 1.0)
                        - jnp.sum(jnp.square(mean), -1), 0.0)
    return jnp.zeros(n_blocks, jnp.float32).at[row_block_ids].add(per_row)
