"""Oracle for the fused cohort aggregation + divergence pass.

Inputs
  deltas [N, D, r]  client-stacked updates (fusion ``a``-style leaf; any
                    2-D trailing shape works, r may be 1)
  W      [N, D]     per-(client,row) combine weights (Eq. 3/4 — rows of a
                    block share the cohort weight; B-weighting folds in here)
  C      [N, D]     divergence cohort mask (Eq. 5)
Outputs
  agg    [D, r]     sum_n W[n,d] * deltas[n,d,:]
  sqsum  [D]        sum_n C[n,d] * ||deltas[n,d,:]||^2
  wsum   [D, r]     sum_n (C[n,d]/cnt_d) * deltas[n,d,:]  (cohort mean)
  cnt    [D]        sum_n C[n,d]

Divergence per block m (Eq. 5) is then
  d_m = sum_{d in block} sqsum[d]/cnt[d] - ||wsum[d]||^2.
"""
from __future__ import annotations

import jax.numpy as jnp


def cohort_agg_divergence_ref(deltas, W, C):
    d32 = deltas.astype(jnp.float32)
    W = W.astype(jnp.float32)
    C = C.astype(jnp.float32)
    agg = jnp.einsum("nd,ndr->dr", W, d32)
    sqsum = jnp.einsum("nd,ndr->d", C, jnp.square(d32))
    cnt = jnp.sum(C, axis=0)
    mean = jnp.einsum("nd,ndr->dr", C, d32) / jnp.maximum(cnt, 1.0)[:, None]
    return agg, sqsum, mean, cnt


def divergence_from_stats(sqsum, mean, cnt, row_block_ids, n_blocks: int):
    """Reduce row stats to per-block divergences (Eq. 5)."""
    per_row = jnp.where(cnt > 0, sqsum / jnp.maximum(cnt, 1.0)
                        - jnp.sum(jnp.square(mean), -1), 0.0)
    return jnp.zeros(n_blocks, jnp.float32).at[row_block_ids].add(per_row)
