"""Fused block-masked LoRA projection as a Pallas TPU kernel.

One pass over the D (contraction) axis accumulates BOTH the frozen base
matmul x@W0 and the LoRA bottleneck u = x@a in VMEM scratch; the final grid
step applies u @ b * scale into the output tile. The modality row-mask is
folded into the x tile load, so absent-modality blocks cost no MXU work
beyond the masked multiply (and, on the A side, allow XLA to skip dead
blocks entirely when the mask is static).

Tiling: grid = (T/bt, F/bf, D/bd); MXU-aligned tiles (128 multiples).
VMEM working set per step: bt*bd (x) + bd*bf (w0) + bd*r (a) + bt*bf (acc)
+ bt*r (u) floats — e.g. bt=bf=bd=256, r<=64: ~0.8 MB, far under the
~16 MB/core VMEM budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w0_ref, a_ref, b_ref, mask_ref, o_ref, acc_ref, u_ref, *,
            scale: float, n_d: int):
    d_idx = pl.program_id(2)

    @pl.when(d_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    xm = x_ref[...].astype(jnp.float32) * mask_ref[...].astype(jnp.float32)[None, :]
    acc_ref[...] += jnp.dot(xm, w0_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)
    u_ref[...] += jnp.dot(xm, a_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)

    @pl.when(d_idx == n_d - 1)
    def _finish():
        lora = jnp.dot(u_ref[...], b_ref[...].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * lora).astype(o_ref.dtype)


def _multi_kernel(idx_ref, x_ref, w0_ref, a_ref, b_ref, mask_ref, o_ref,
                  acc_ref, u_ref, *, scale: float, n_d: int):
    del idx_ref  # consumed by the BlockSpec index maps (adapter gather)
    d_idx = pl.program_id(2)

    @pl.when(d_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    xm = x_ref[...].astype(jnp.float32) * mask_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(xm, w0_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)
    u_ref[...] += jnp.dot(xm, a_ref[0].astype(jnp.float32),
                          preferred_element_type=jnp.float32)

    @pl.when(d_idx == n_d - 1)
    def _finish():
        lora = jnp.dot(u_ref[...], b_ref[0].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * lora).astype(o_ref.dtype)


def mdlora_matmul_multi_pallas(x, w0, a, b, adapter_idx, row_mask, scale,
                               bf: int = 256, bd: int = 256,
                               interpret: bool = False):
    """Gathered multi-adapter decode: one fused pass serves a mixed batch.

    x: [B, D]; w0: [D, F]; a: [A, D, r]; b: [A, r, F]; adapter_idx: [B];
    row_mask: [B, D] -> [B, F].

    ``adapter_idx`` is scalar-prefetched so the BlockSpec index maps can DMA
    each row's adapter tiles straight out of the stacked [A, ...] store —
    the per-request [B, D, r] gathered weight copies never exist. Rows tile
    one at a time (each row may use a different adapter); the D axis streams
    innermost with the base accumulator and the LoRA bottleneck u resident
    in VMEM scratch, exactly like the single-adapter kernel.
    """
    B, D = x.shape
    F = w0.shape[1]
    r = a.shape[2]
    bf, bd = min(bf, F), min(bd, D)
    assert F % bf == 0 and D % bd == 0, (B, F, D, bf, bd)
    n_d = D // bd

    grid = (B, F // bf, n_d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bd), lambda i, j, k, idx: (i, k)),  # x
            pl.BlockSpec((bd, bf), lambda i, j, k, idx: (k, j)),  # w0
            pl.BlockSpec((1, bd, r), lambda i, j, k, idx: (idx[i], k, 0)),
            pl.BlockSpec((1, r, bf), lambda i, j, k, idx: (idx[i], 0, j)),
            pl.BlockSpec((1, bd), lambda i, j, k, idx: (i, k)),  # row_mask
        ],
        out_specs=pl.BlockSpec((1, bf), lambda i, j, k, idx: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((1, bf), jnp.float32),
            pltpu.VMEM((1, r), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_multi_kernel, scale=float(scale), n_d=n_d),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, F), x.dtype),
        interpret=interpret,
    )(adapter_idx, x, w0, a, b, row_mask)


def mdlora_matmul_pallas(x, w0, a, b, row_mask, scale,
                         bt: int = 256, bf: int = 256, bd: int = 256,
                         interpret: bool = False):
    """x: [T, D]; w0: [D, F]; a: [D, r]; b: [r, F]; row_mask: [D] -> [T, F]."""
    T, D = x.shape
    F = w0.shape[1]
    r = a.shape[1]
    bt, bf, bd = min(bt, T), min(bf, F), min(bd, D)
    assert T % bt == 0 and F % bf == 0 and D % bd == 0, (T, F, D, bt, bf, bd)
    n_d = D // bd

    grid = (T // bt, F // bf, n_d)
    return pl.pallas_call(
        functools.partial(_kernel, scale=float(scale), n_d=n_d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, bd), lambda i, j, k: (i, k)),  # x
            pl.BlockSpec((bd, bf), lambda i, j, k: (k, j)),  # w0
            pl.BlockSpec((bd, r), lambda i, j, k: (k, 0)),  # a
            pl.BlockSpec((r, bf), lambda i, j, k: (0, j)),  # b
            pl.BlockSpec((bd,), lambda i, j, k: (k,)),  # row_mask
        ],
        out_specs=pl.BlockSpec((bt, bf), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((T, F), x.dtype),
        scratch_shapes=[
            # fp32 accumulators live in VMEM across the D-axis grid steps
            pltpu.VMEM((bt, bf), jnp.float32),
            pltpu.VMEM((bt, r), jnp.float32),
        ],
        interpret=interpret,
    )(x, w0, a, b, row_mask)
