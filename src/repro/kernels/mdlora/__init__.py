from repro.kernels.mdlora.ops import (block_row_mask, block_row_masks,
                                      mdlora_matmul, mdlora_matmul_multi)

__all__ = ["block_row_mask", "block_row_masks", "mdlora_matmul",
           "mdlora_matmul_multi"]
