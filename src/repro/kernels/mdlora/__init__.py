from repro.kernels.mdlora.ops import mdlora_matmul
