"""Pure-jnp oracle for the fused block-LoRA projection.

    y = (x * row_mask) @ W0  +  ((x * row_mask) @ a) @ b * scale

``row_mask`` ([D]) zeroes the input rows of absent modality blocks (Eq. 1/2:
missing modalities contribute exactly nothing to the fusion layer, and their
A-blocks receive zero gradient).
"""
from __future__ import annotations

import jax.numpy as jnp


def mdlora_matmul_ref(x, w0, a, b, row_mask, scale):
    xm = x * row_mask[None, :].astype(x.dtype)
    base = xm.astype(jnp.float32) @ w0.astype(jnp.float32)
    lora = (xm.astype(jnp.float32) @ a.astype(jnp.float32)) @ \
        b.astype(jnp.float32) * scale
    return (base + lora).astype(x.dtype)
