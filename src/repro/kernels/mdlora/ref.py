"""Pure-jnp oracle for the fused block-LoRA projection.

    y = (x * row_mask) @ W0  +  ((x * row_mask) @ a) @ b * scale

``row_mask`` ([D]) zeroes the input rows of absent modality blocks (Eq. 1/2:
missing modalities contribute exactly nothing to the fusion layer, and their
A-blocks receive zero gradient).
"""
from __future__ import annotations

import jax.numpy as jnp


def mdlora_matmul_ref(x, w0, a, b, row_mask, scale):
    xm = x * row_mask[None, :].astype(x.dtype)
    base = xm.astype(jnp.float32) @ w0.astype(jnp.float32)
    lora = (xm.astype(jnp.float32) @ a.astype(jnp.float32)) @ \
        b.astype(jnp.float32) * scale
    return (base + lora).astype(x.dtype)


def mdlora_matmul_multi_ref(x, w0, a, b, adapter_idx, row_mask, scale):
    """Gathered multi-adapter oracle (S-LoRA/punica-style batched decode).

        y[i] = (x[i] * mask[i]) @ W0
             + ((x[i] * mask[i]) @ a[idx[i]]) @ b[idx[i]] * scale

    x: [B, D] one token per request; w0: [D, F] shared frozen base;
    a: [A, D, r] / b: [A, r, F] the stacked per-client adapter store;
    adapter_idx: [B] int row -> adapter slot; row_mask: [B, D] per-request
    modality availability over the fusion input rows (None = all present).
    The gather is per *row*, so the batch can mix adapters freely — this is
    the semantics the Pallas kernel reproduces without materializing the
    [B, D, r] gathered weight copies.
    """
    if row_mask is None:
        xm = x
    else:
        xm = x * row_mask.astype(x.dtype)
    xm32 = xm.astype(jnp.float32)
    base = xm32 @ w0.astype(jnp.float32)
    a_g = jnp.take(a, adapter_idx, axis=0).astype(jnp.float32)  # [B, D, r]
    b_g = jnp.take(b, adapter_idx, axis=0).astype(jnp.float32)  # [B, r, F]
    u = jnp.einsum("bd,bdr->br", xm32, a_g)
    lora = jnp.einsum("br,brf->bf", u, b_g) * scale
    return (base + lora).astype(x.dtype)
