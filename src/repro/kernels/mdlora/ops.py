"""jit'd public wrapper for the fused block-LoRA projection."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.mdlora.kernel import mdlora_matmul_pallas
from repro.kernels.mdlora.ref import mdlora_matmul_ref


def block_row_mask(block_dims, modality_mask) -> jnp.ndarray:
    """[M] modality availability -> [D] row mask over the fusion input."""
    reps = np.asarray(block_dims, np.int32)
    mm = jnp.asarray(modality_mask, jnp.float32)
    return jnp.repeat(mm, jnp.asarray(reps), total_repeat_length=int(reps.sum()))


@functools.partial(jax.jit, static_argnames=("scale", "impl", "interpret",
                                             "bt", "bf", "bd"))
def mdlora_matmul(x, w0, a, b, row_mask, scale: float = 2.0,
                  impl: str = "xla", interpret: bool = False,
                  bt: int = 256, bf: int = 256, bd: int = 256):
    """y = (x*mask)@W0 + ((x*mask)@a)@b*scale.

    impl="pallas" is the TPU deployment path (tests run it with
    interpret=True); impl="xla" is the portable fallback the CPU dry-run
    compiles.
    """
    if impl == "pallas":
        return mdlora_matmul_pallas(x, w0, a, b, row_mask, scale,
                                    bt=bt, bf=bf, bd=bd, interpret=interpret)
    return mdlora_matmul_ref(x, w0, a, b, row_mask, scale)
