"""jit'd public wrappers for the fused block-LoRA projections.

``interpret=None`` resolves to the backend default (interpret only on CPU —
see kernels/runtime.py). Block sizes default to ``None`` and resolve through
the shared autotuner (kernels/cohort_agg/autotune.py): largest-divisor
heuristic on interpret/XLA backends, timed sweep on compiled Pallas.
Explicit block sizes are snapped to the largest divisor of the tiled axis,
so blocking survives non-divisible shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cohort_agg.autotune import (largest_divisor,
                                               select_mdlora_blocks)
from repro.kernels.mdlora.kernel import (mdlora_matmul_multi_pallas,
                                         mdlora_matmul_pallas)
from repro.kernels.mdlora.ref import (mdlora_matmul_multi_ref,
                                      mdlora_matmul_ref)
from repro.kernels.runtime import resolve_interpret


def block_row_mask(block_dims, modality_mask) -> jnp.ndarray:
    """[M] modality availability -> [D] row mask over the fusion input."""
    reps = np.asarray(block_dims, np.int32)
    mm = jnp.asarray(modality_mask, jnp.float32)
    return jnp.repeat(mm, jnp.asarray(reps), total_repeat_length=int(reps.sum()))


def block_row_masks(block_dims, modality_masks) -> jnp.ndarray:
    """[B, M] per-request availability -> [B, D] row masks (batched)."""
    reps = np.asarray(block_dims, np.int32)
    mm = jnp.asarray(modality_masks, jnp.float32)
    return jnp.repeat(mm, jnp.asarray(reps), axis=-1,
                      total_repeat_length=int(reps.sum()))


def _resolve_blocks(T, D, F, r, impl, interpret, bt, bf, bd, multi=False,
                    n_adapters=1):
    if bt is None or bf is None or bd is None:
        tt, tf, td = select_mdlora_blocks((T, D, F, r), impl=impl,
                                          interpret=interpret, multi=multi,
                                          n_adapters=n_adapters)
        bt, bf, bd = bt or tt, bf or tf, bd or td
    return (1 if multi else largest_divisor(T, bt), largest_divisor(F, bf),
            largest_divisor(D, bd))


@functools.partial(jax.jit, static_argnames=("scale", "impl", "interpret",
                                             "bt", "bf", "bd"))
def _matmul_jit(x, w0, a, b, row_mask, scale, impl, interpret, bt, bf, bd):
    if impl == "pallas":
        return mdlora_matmul_pallas(x, w0, a, b, row_mask, scale,
                                    bt=bt, bf=bf, bd=bd, interpret=interpret)
    return mdlora_matmul_ref(x, w0, a, b, row_mask, scale)


def mdlora_matmul(x, w0, a, b, row_mask, scale: float = 2.0,
                  impl: str = "xla", interpret: bool | None = None,
                  bt: int | None = None, bf: int | None = None,
                  bd: int | None = None):
    """y = (x*mask)@W0 + ((x*mask)@a)@b*scale.

    impl="pallas" is the TPU deployment path (interpret resolves per
    backend); impl="xla" is the portable fallback the CPU dry-run compiles.
    """
    interpret = resolve_interpret(interpret)
    bt, bf, bd = _resolve_blocks(x.shape[0], x.shape[1], w0.shape[1],
                                 a.shape[1], impl, interpret, bt, bf, bd)
    return _matmul_jit(x, w0, a, b, row_mask, float(scale), impl, interpret,
                       bt, bf, bd)


@functools.partial(jax.jit, static_argnames=("scale", "impl", "interpret",
                                             "bf", "bd"))
def _matmul_multi_jit(x, w0, a, b, adapter_idx, row_mask, scale, impl,
                      interpret, bf, bd):
    if row_mask is None:
        row_mask = jnp.ones(x.shape, jnp.float32)
    if impl == "pallas":
        return mdlora_matmul_multi_pallas(x, w0, a, b, adapter_idx, row_mask,
                                          scale, bf=bf, bd=bd,
                                          interpret=interpret)
    return mdlora_matmul_multi_ref(x, w0, a, b, adapter_idx, row_mask, scale)


def mdlora_matmul_multi(x, w0, a, b, adapter_idx, row_mask=None,
                        scale: float = 2.0, impl: str = "xla",
                        interpret: bool | None = None, bf: int | None = None,
                        bd: int | None = None):
    """Gathered multi-adapter projection: one fused call serves a batch of
    requests that each carry their own modality-block adapter.

    x: [B, D] (one token per request); w0: [D, F] shared base; a: [A, D, r] /
    b: [A, r, F] stacked adapter store; adapter_idx: [B] row -> slot;
    row_mask: [B, D] per-request modality row masks (None = all present).
    """
    interpret = resolve_interpret(interpret)
    _, bf, bd = _resolve_blocks(x.shape[0], x.shape[1], w0.shape[1],
                                a.shape[2], impl, interpret, 1, bf, bd,
                                multi=True, n_adapters=a.shape[0])
    adapter_idx = jnp.asarray(adapter_idx, jnp.int32)
    return _matmul_multi_jit(x, w0, a, b, adapter_idx, row_mask,
                             float(scale), impl, interpret, bf, bd)
