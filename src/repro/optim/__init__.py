from repro.optim.optimizers import (adam_init, adam_update, sgd_init,
                                    sgd_update, make_optimizer,
                                    cosine_schedule, linear_warmup_cosine)
