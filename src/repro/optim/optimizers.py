"""Pure-JAX optimizers (no optax in this environment).

Adam/AdamW keep fp32 moments regardless of param dtype; ``make_optimizer``
returns an (init, update) pair over arbitrary pytrees. ZeRO-1 sharding of the
moment buffers is applied at the sharding-spec level (dist/sharding.py) — the
math here is sharding-agnostic.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


def _f32(tree):
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), tree)


def adam_init(params: Any) -> dict:
    return {"m": _f32(params), "v": _f32(params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params: Any, grads: Any, state: dict, lr: float | Array,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                weight_decay: float = 0.0) -> tuple[Any, dict]:
    t = state["t"] + 1
    tf = t.astype(jnp.float32)
    bc1 = 1.0 - b1**tf
    bc2 = 1.0 - b2**tf

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        step = lr * (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        if weight_decay:
            step = step + lr * weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda x: x[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda x: x[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda x: x[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "t": t}


def sgd_init(params: Any) -> dict:
    return {"mom": _f32(params)}


def sgd_update(params: Any, grads: Any, state: dict, lr: float | Array,
               momentum: float = 0.9) -> tuple[Any, dict]:
    def upd(p, g, m):
        m_new = momentum * m + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * m_new).astype(p.dtype), m_new

    out = jax.tree.map(upd, params, grads, state["mom"])
    new_params = jax.tree.map(lambda x: x[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda x: x[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mom": new_m}


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], dict]
    update: Callable[..., tuple[Any, dict]]


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adam":
        return Optimizer(adam_init,
                         lambda p, g, s, lr: adam_update(p, g, s, lr, **kw))
    if name == "sgd":
        return Optimizer(sgd_init,
                         lambda p, g, s, lr: sgd_update(p, g, s, lr, **kw))
    raise ValueError(name)


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def fn(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return base_lr * (min_frac + (1 - min_frac) * 0.5 *
                          (1 + jnp.cos(jnp.pi * frac)))
    return fn


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         min_frac: float = 0.05):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), min_frac)

    def fn(step):
        w = jnp.clip(step / max(warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, base_lr * w, cos(step - warmup))
    return fn
