"""Distributed substrate: sharding specs (dist/sharding.py) and the update
compression codecs used on the FL uplink.

Compression is applied to client deltas before upload (Eq. 8's on-demand
volume composes with these): int8 symmetric quantization (per-leaf scale)
and top-k sparsification with error feedback (the dropped mass is carried
to the next round, so the compressed stream is unbiased in the limit).
``compressed_size_bytes`` is the accounting used by the comm simulator.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(tree: Any) -> tuple[Any, Any]:
    """Per-leaf symmetric int8: scale = max|x|/127, q = round(x/scale)."""
    pairs = jax.tree.map(lambda x: _quantize_leaf(x.astype(jnp.float32)),
                         tree)
    qt = jax.tree.map(lambda p: p[0], pairs,
                      is_leaf=lambda x: isinstance(x, tuple))
    sc = jax.tree.map(lambda p: p[1], pairs,
                      is_leaf=lambda x: isinstance(x, tuple))
    return qt, sc


def dequantize_int8(qtree: Any, scales: Any) -> Any:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qtree, scales)


def _quantize_leaf(x32):
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantize_int8_ef(tree: Any, error: Any | None = None
                     ) -> tuple[Any, Any, Any]:
    """Int8 quantization with error feedback (client-side state).

    Quantizes ``tree + error`` and returns ``(qtree, scales, residual)``
    where residual = (tree + error) - dequant(qtree) is the next round's
    ``error``. Summed over rounds, the dequantized uploads telescope to the
    uncompressed stream minus the final residual, so the compressed uplink
    is unbiased in the limit (same contract as ``topk_sparsify``).
    """
    if error is None:
        error = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tree)

    def q(x, e):
        x32 = x.astype(jnp.float32) + e
        qv, scale = _quantize_leaf(x32)
        return qv, scale, x32 - qv.astype(jnp.float32) * scale

    triples = jax.tree.map(q, tree, error)
    is_t = lambda x: isinstance(x, tuple)  # noqa: E731
    return tuple(jax.tree.map(lambda p, i=i: p[i], triples, is_leaf=is_t)
                 for i in range(3))


def quantize_int8_stacked(tree: Any, error: Any | None = None
                          ) -> tuple[Any, Any, Any]:
    """Per-client int8 for client-stacked trees ([K, ...] leaves).

    One symmetric scale per (client, leaf) — scales leaves are [K] — so a
    whole dispatch batch quantizes in one vectorized shot; this is the
    uplink layout ``CohortAggBuffer.push_quantized`` ingests natively.
    ``error`` ([K, ...] residual stack) carries per-client error feedback.
    Returns ``(qtree, scales, residual)`` like ``quantize_int8_ef``.
    """
    def q(x, e):
        x32 = x.astype(jnp.float32) + (0.0 if e is None else e)
        red = tuple(range(1, x32.ndim))
        scale = jnp.maximum(jnp.max(jnp.abs(x32), axis=red), 1e-12) / 127.0
        sb = scale.reshape((-1,) + (1,) * (x32.ndim - 1))
        qv = jnp.clip(jnp.round(x32 / sb), -127, 127).astype(jnp.int8)
        return qv, scale, x32 - qv.astype(jnp.float32) * sb

    if error is None:
        triples = jax.tree.map(lambda x: q(x, None), tree)
    else:
        triples = jax.tree.map(q, tree, error)
    is_t = lambda x: isinstance(x, tuple)  # noqa: E731
    return tuple(jax.tree.map(lambda p, i=i: p[i], triples, is_leaf=is_t)
                 for i in range(3))


def dequantize_int8_stacked(qtree: Any, scales: Any) -> Any:
    """Inverse of ``quantize_int8_stacked`` ([K] scale leaves broadcast)."""
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32)
        * s.reshape((-1,) + (1,) * (q.ndim - 1)), qtree, scales)


def topk_sparsify(tree: Any, frac: float, error: Any | None = None
                  ) -> tuple[Any, Any]:
    """Magnitude top-k with error feedback.

    Keeps ceil(frac * size) entries per leaf of ``tree + error``; the
    residual (dropped mass) is returned as the next round's ``error``.
    """
    if error is None:
        error = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tree)

    def sp(x, e):
        x32 = x.astype(jnp.float32) + e
        flat = x32.reshape(-1)
        k = max(1, int(math.ceil(frac * flat.size)))
        thresh = jnp.sort(jnp.abs(flat))[-k]
        mask = (jnp.abs(flat) >= thresh).astype(jnp.float32)
        # break ties deterministically: keep at most k (first-come in sort)
        sparse = (flat * mask).reshape(x32.shape)
        return sparse, x32 - sparse

    pairs = jax.tree.map(sp, tree, error)
    sparse = jax.tree.map(lambda p: p[0], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return sparse, err


def compressed_size_bytes(tree: Any, mode: str, frac: float | None = None
                          ) -> int:
    """Uplink bytes for one update under a codec.

    none: 4B/param. int8: 1B/param + 4B scale per leaf. topk: kept values
    as (4B value + 4B index) pairs.
    """
    leaves = jax.tree.leaves(tree)
    if mode == "none":
        return sum(4 * x.size for x in leaves)
    if mode == "int8":
        return sum(x.size + 4 for x in leaves)
    if mode == "topk":
        assert frac is not None
        return sum(8 * max(1, int(math.ceil(frac * x.size))) for x in leaves)
    raise ValueError(mode)
