"""Partition-spec construction for the production meshes (launch/mesh.py).

Three strategies, picked per (arch, step-kind) by ``pick_strategy``:

  fsdp        params sharded over the data axes (ZeRO-3-style; moments
              follow their params via ``opt_state_specs`` = ZeRO-1)
  tp          params sharded over the ``model`` axis (Megatron-style);
              the serving default — decode batches are too small to feed
              the data axis
  replicated  small models: replicate params, shard only the batch

Specs are pure ``PartitionSpec`` trees built from ``mesh.axis_names`` and
``mesh.shape`` only (dry-runnable against fake meshes); ``to_named`` binds
them to a real mesh. A dim is only ever sharded when the axis product
divides it — jit input requirement — so every produced spec is valid by
construction.

``act_hint`` is the activation-sharding hook the model code calls with
logical axis labels ("batch" / "model" / "model_pad" / None); it is a no-op
until ``set_activation_mesh`` installs a mesh (single-device tests never pay
for it).
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# activation hints
# ---------------------------------------------------------------------------

_ACT: dict = {"mesh": None, "batch_axes": (), "tp": True}


def set_activation_mesh(mesh, tp: bool = True,
                        batch_axes: tuple[str, ...] | None = None) -> None:
    """Install (or clear, with ``mesh=None``) the activation mesh used by
    ``act_hint``. ``batch_axes`` defaults to the data axes of the mesh."""
    _ACT["mesh"] = mesh
    _ACT["tp"] = tp
    if mesh is None:
        _ACT["batch_axes"] = ()
    else:
        _ACT["batch_axes"] = (tuple(batch_axes) if batch_axes is not None
                              else data_axes(mesh))


def _resolve_label(label, mesh) -> tuple[str, ...]:
    if label is None:
        return ()
    if label == "batch":
        return tuple(_ACT["batch_axes"])
    if label in ("model", "model_pad"):
        return ("model",) if (_ACT["tp"] and "model" in mesh.axis_names) else ()
    if label in mesh.axis_names:
        return (label,)
    return ()


def act_hint(x, *labels):
    """Constrain an activation's sharding by logical axis labels.

    Labels map per dim: "batch" -> the installed batch axes, "model"/"model_pad"
    -> the model axis when TP is active, None -> unsharded. Axes that do not
    evenly divide their dim are dropped (model_pad covers padded head dims).
    No mesh installed -> returns ``x`` unchanged.
    """
    mesh = _ACT["mesh"]
    if mesh is None:
        return x
    entries = []
    nontrivial = False
    for dim, label in zip(x.shape, labels):
        axes = _resolve_label(label, mesh)
        if axes and dim % _axis_product(mesh, axes) == 0:
            entries.append(axes[0] if len(axes) == 1 else axes)
            nontrivial = True
        else:
            entries.append(None)
    if not nontrivial:
        return x
    spec = P(*entries)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# strategy selection
# ---------------------------------------------------------------------------


def _approx_param_count(cfg) -> float:
    """Coarse parameter-count estimate from the config dims alone."""
    d, L = cfg.d_model, cfg.n_layers
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    attn = 0.0
    if cfg.n_heads:
        hd = cfg.head_dim or d // cfg.n_heads
        attn = d * hd * (2 * cfg.n_heads + 2 * cfg.n_kv_heads)
    ff = 3.0 * d * cfg.d_ff * max(cfg.n_experts, 1)
    ssm = 3.0 * d * cfg.d_inner if cfg.d_inner else 0.0
    return emb + L * (attn + ff + ssm)


def pick_strategy(cfg, kind: str) -> str:
    """-> "fsdp" | "tp" | "replicated" for one (arch, step-kind) cell."""
    if kind != "train":  # prefill / decode / serve: small batch, TP it
        return "tp"
    if cfg.family == "moe":
        # expert-parallel folds into TP here: the stacked expert FF dims are
        # the only axes large enough to keep 16-way model sharding busy
        return "tp"
    if _approx_param_count(cfg) < 3e9:
        return "replicated"
    return "fsdp"


# ---------------------------------------------------------------------------
# spec construction
# ---------------------------------------------------------------------------


def data_axes(mesh) -> tuple[str, ...]:
    """The client/batch mesh axes, outermost first (pod before data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_product(mesh, axes: tuple[str, ...]) -> int:
    return int(math.prod(mesh.shape[a] for a in axes))


def _shard_largest_dim(shape, axes: tuple[str, ...], mesh) -> P:
    """Spec sharding the largest evenly-divisible dim over ``axes``
    (ties -> the trailing dim, matching row-major layout locality)."""
    if not axes:
        return P()
    size = _axis_product(mesh, axes)
    best = -1
    for i, d in enumerate(shape):
        if d % size == 0 and d >= size and (best < 0 or d >= shape[best]):
            best = i
    if best < 0:
        return P()
    entries = [None] * len(shape)
    entries[best] = axes[0] if len(axes) == 1 else axes
    return P(*entries)


def param_specs(cfg, params: Any, mesh, train: bool = False,
                strategy: str | None = None) -> Any:
    """PartitionSpec tree mirroring ``params`` (ShapeDtypeStructs or arrays).

    Every sharded dim divides its axis product — valid jit input specs for
    all archs on the production meshes by construction.
    """
    strategy = strategy or pick_strategy(cfg, "train" if train else "serve")
    if strategy == "replicated":
        return jax.tree.map(lambda x: P(), params)
    axes = data_axes(mesh) if strategy == "fsdp" else ("model",)
    if not set(axes) <= set(mesh.axis_names):
        return jax.tree.map(lambda x: P(), params)
    return jax.tree.map(lambda x: _shard_largest_dim(x.shape, axes, mesh),
                        params)


def opt_state_specs(pspec_tr: Any, opt: Any, mesh) -> Any:
    """Adam/SGD state specs: moment trees follow their params (ZeRO-1 via
    fsdp param specs), step counters replicate."""
    return {k: (pspec_tr if k in ("m", "v", "mom")
                else jax.tree.map(lambda x: P(), v))
            for k, v in opt.items()}


def batch_specs(batch: Any, mesh, cfg, strategy: str | None = None) -> Any:
    """Shard the leading (global-batch) dim over the data axes; fsdp and
    replicated training additionally fold the model axis into the batch so
    every chip carries examples (dryrun.py's hybrid note)."""
    axes = data_axes(mesh)
    if strategy in ("fsdp", "replicated") and "model" in mesh.axis_names:
        axes = axes + ("model",)

    def spec(x):
        for cand in (axes, data_axes(mesh)):
            if (cand and len(x.shape) >= 1
                    and x.shape[0] % _axis_product(mesh, cand) == 0
                    and x.shape[0] >= _axis_product(mesh, cand)):
                entry = cand[0] if len(cand) == 1 else cand
                return P(*([entry] + [None] * (len(x.shape) - 1)))
        return P()

    return jax.tree.map(spec, batch)


def cache_specs(cfg, caches: Any, mesh) -> Any:
    """KV/SSM decode caches: shard the batch dim over the data axes."""
    return batch_specs(caches, mesh, cfg)


def to_named(mesh, tree: Any) -> Any:
    """Bind a PartitionSpec tree to a real mesh -> NamedSharding tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))
