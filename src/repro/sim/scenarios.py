"""Scenario matrix: one frozen spec -> dataset + fleet + strategy + runtime.

Every experiment used to wire `make_har_dataset` / `make_fleet` /
`AsyncFedConfig` together by hand with copy-pasted kwargs, which is why the
repo only ever ran the paper's single coupled-heterogeneity scenario. A
``ScenarioSpec`` is the single constructor input for both async runtimes:

    spec = get_scenario("static30")
    run, sc = make_run(spec)                      # heap runtime
    run.run(sc.dataset)

The missing-modality side is a pluggable generator family in the
fed-multimodal style (10/30/50% ratios):

    none       the paper's coupled fleet — possession is tied to device
               tier at construction (full=all, mid=2, low=1 modalities)
    static     per-client masks drawn once, *exact* global missing count
               round(ratio * N * M), every client keeps >= 1 modality
    tiered     missing correlated with device tier: the fastest tier drops
               nothing, the slowest drops ~2x the ratio, fleet-average ~=
               ratio (reproduces the paper's coupled heterogeneity on an
               arbitrary fleet)
    streaming  time-varying masks — modalities appear/disappear mid-run on
               per-(client, modality) duty cycles; a per-client anchor
               modality never drops. Masks are a *pure function of
               (seed, client, modality, sim-time)*, never of event order,
               so the heap and vectorized runtimes stay history-equivalent
               (tests/test_scenarios.py).

Determinism: every draw is keyed by (spec.seed, salt[, client]) with
``np.random.default_rng`` sequence seeds — independent of runtime
interleaving and of fleet subset order.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.core import strategies
from repro.data.registry import get_provider
from repro.sim.devices import FleetConfig, make_fleet, scale_fleet
from repro.sim.faults import FaultModel

MISSING_GENERATORS = ("none", "static", "tiered", "streaming")

# rng stream salts — distinct sub-streams of spec.seed
_STATIC_SALT = 0x57A7
_TIER_SALT = 0x7123
_STREAM_SALT = 0x5E4A
_SCALE_SALT = 0x5CA1


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """The whole experiment in one frozen value.

    ``strategy`` is a name in :mod:`repro.core.strategies`'s registry;
    ``strategy_args`` is a tuple of ``(field, value)`` pairs applied as
    overrides (tuples keep the spec hashable). The training knobs mirror
    FedConfig so ``AsyncFedConfig.from_scenario(spec)`` needs nothing else.
    """
    name: str
    # data
    dataset: str = "pamap2"  # provider name (data/registry.py)
    alpha: float = 1.0  # Dirichlet concentration of client class priors
    windows_per_subject: int = 240
    # missing-modality generator
    missing: str = "none"  # none | static | tiered | streaming
    missing_ratio: float = 0.3  # 0.1 / 0.3 / 0.5 in the sweep
    stream_period: float = 40.0  # mean sim-seconds per on/off duty cycle
    # fleet
    fleet: tuple[int, int, int] = (3, 3, 2)  # (n_full, n_mid, n_low)
    n_clients: int | None = None  # scale_fleet target; None = sum(fleet)
    hetero_scale: float | None = None  # Full/Low compute gap (10/55/100)
    # protocol
    strategy: str = "async_relief"
    strategy_args: tuple[tuple[str, Any], ...] = ()
    uplink_codec: str = "none"  # none | int8
    faults: FaultModel | None = None
    # model
    backbone: str = "cnn"
    small_model: bool = True
    # training/runtime knobs (consumed by AsyncFedConfig.from_scenario)
    rounds: int = 20
    local_epochs: int = 5
    steps_per_epoch: int = 4
    batch_size: int = 32
    lr: float = 1e-3
    eval_every: int = 5
    t_overhead: float = 0.05
    utilization: float = 2e-5
    jitter_sigma: float = 0.0
    total_updates: int | None = None
    grad_mode: str = "dispatch"
    seed: int = 0

    def __post_init__(self):
        if self.missing not in MISSING_GENERATORS:
            raise ValueError(f"missing must be one of {MISSING_GENERATORS}, "
                             f"got {self.missing!r}")
        if not 0.0 <= self.missing_ratio < 1.0:
            raise ValueError("missing_ratio must be in [0, 1)")

    def build_strategy(self) -> strategies.Strategy:
        return strategies.get(self.strategy, **dict(self.strategy_args))


# ---------------------------------------------------------------------------
# missing-modality generators
# ---------------------------------------------------------------------------


def static_missing_mask(base: np.ndarray, ratio: float,
                        seed: int) -> np.ndarray:
    """Drop exactly ``round(ratio * N * M)`` (client, modality) pairs from
    the ``base`` possession mask, never leaving a client with 0 modalities.

    A seeded permutation of all pairs is walked until the target count is
    reached, skipping drops that would empty a client — deterministic in
    (seed, N, M) and independent of anything runtime-side. Feasible for
    ratio <= (M-1)/M on a full base.
    """
    base = np.asarray(base, bool)
    N, M = base.shape
    mask = base.copy()
    target = int(round(ratio * N * M))
    rng = np.random.default_rng([seed, _STATIC_SALT])
    dropped = 0
    for p in rng.permutation(N * M):
        if dropped >= target:
            break
        n, m = divmod(int(p), M)
        if mask[n, m] and mask[n].sum() > 1:
            mask[n, m] = False
            dropped += 1
    if dropped < target:
        raise ValueError(f"cannot drop {target} pairs while keeping every "
                         f"client >=1 modality (N={N}, M={M})")
    return mask


def device_tiers(fleet: FleetConfig) -> np.ndarray:
    """[N] tier index, 0 = fastest, from the distinct compute levels."""
    levels = np.unique(fleet.tops)[::-1]  # descending
    return np.searchsorted(-levels, -fleet.tops).astype(np.int64)


def tiered_missing_mask(base: np.ndarray, tiers: np.ndarray, ratio: float,
                        seed: int) -> np.ndarray:
    """Missing correlated with device tier: tier t of T drops a
    ``ratio * 2t/(T-1)`` fraction of its modalities (fastest tier drops 0,
    slowest ~2x ratio; fleet-average ~= ratio for balanced tiers), each
    client keeping >= 1. Which modalities drop is a per-client seeded
    permutation, so the mask is order-free."""
    base = np.asarray(base, bool)
    tiers = np.asarray(tiers)
    N, M = base.shape
    T = int(tiers.max()) + 1
    mask = base.copy()
    for n in range(N):
        frac = ratio * (2.0 * tiers[n] / (T - 1)) if T > 1 else ratio
        n_drop = min(int(round(frac * M)), int(base[n].sum()) - 1)
        if n_drop <= 0:
            continue
        rng = np.random.default_rng([seed, _TIER_SALT, n])
        owned = np.nonzero(base[n])[0]
        mask[n, rng.permutation(owned)[:n_drop]] = False
    return mask


@dataclasses.dataclass(frozen=True, eq=False)
class StreamingSchedule:
    """Time-varying modality availability, evaluated lazily at dispatch.

    Client n's modality m is ON at sim-time t iff

        frac(t / period[n, m] + phase[n, m]) < 1 - ratio

    intersected with the static possession ``base`` and with the per-client
    ``anchor`` modality forced always-on (so allocation always has >= 1
    accessible group). Pure in (t, n, m): both async runtimes evaluating the
    same (time, client) dispatch get bit-identical masks regardless of
    event interleaving, which is what keeps heap/vectorized history parity.
    """
    period: np.ndarray  # [N, M] sim-seconds per duty cycle
    phase: np.ndarray  # [N, M] in [0, 1)
    duty: float  # on-fraction = 1 - missing_ratio
    anchor: np.ndarray  # [N] always-on modality per client
    base: np.ndarray  # [N, M] static possession

    @property
    def N(self) -> int:
        return self.base.shape[0]

    @property
    def M(self) -> int:
        return self.base.shape[1]

    def masks_at(self, t: float, idx: np.ndarray | None = None) -> np.ndarray:
        """[B, M] live masks for clients ``idx`` (None = whole fleet)."""
        sl = slice(None) if idx is None else np.asarray(idx)
        on = ((t / self.period[sl] + self.phase[sl]) % 1.0) < self.duty
        out = on & self.base[sl]
        rows = np.arange(out.shape[0])
        anchor = self.anchor[sl]
        out[rows, anchor] = self.base[sl][rows, anchor]
        return out


def streaming_schedule(base: np.ndarray, ratio: float, period: float,
                       seed: int) -> StreamingSchedule:
    """Build the per-(client, modality) duty cycles: periods log-uniform in
    [period/e^.4, period*e^.4] (clients never toggle in lockstep), phases
    uniform, anchor a seeded choice among each client's possessed set."""
    base = np.asarray(base, bool)
    N, M = base.shape
    rng = np.random.default_rng([seed, _STREAM_SALT])
    per = period * np.exp(rng.uniform(-0.4, 0.4, (N, M)))
    phase = rng.random((N, M))
    anchor = np.array([rng.choice(np.nonzero(base[n])[0]) for n in range(N)],
                      np.int64)
    return StreamingSchedule(per, phase, 1.0 - ratio, anchor, base.copy())


# ---------------------------------------------------------------------------
# scenario construction
# ---------------------------------------------------------------------------


def build_fleet(spec: ScenarioSpec) -> FleetConfig:
    """Fleet for a spec. ``missing="none"`` keeps the paper's coupled
    possession (mid=2 modalities, low=1); every other generator starts from
    full possession on all tiers and drops via its own mechanism (static/
    tiered mutate the mask here; streaming keeps the full base and toggles
    at dispatch via the schedule on AsyncFedConfig)."""
    provider = get_provider(spec.dataset)
    M = len(provider.modalities())
    n_full, n_mid, n_low = spec.fleet
    if spec.missing == "none":
        fleet = make_fleet(n_full, n_mid, n_low, M=M,
                           mid_modalities=tuple(range(min(2, M))),
                           low_modalities=(0,),
                           hetero_scale=spec.hetero_scale)
    else:
        full = tuple(range(M))
        fleet = make_fleet(n_full, n_mid, n_low, M=M, mid_modalities=full,
                           low_modalities=full,
                           hetero_scale=spec.hetero_scale)
    if spec.n_clients is not None and spec.n_clients != fleet.N:
        fleet = scale_fleet(fleet, spec.n_clients,
                            np.random.default_rng([spec.seed, _SCALE_SALT]))
    if spec.missing == "static":
        fleet.modality_mask = static_missing_mask(
            fleet.modality_mask, spec.missing_ratio, spec.seed)
    elif spec.missing == "tiered":
        fleet.modality_mask = tiered_missing_mask(
            fleet.modality_mask, device_tiers(fleet), spec.missing_ratio,
            spec.seed)
    return fleet


def schedule_for(spec: ScenarioSpec,
                 fleet: FleetConfig | None = None) -> StreamingSchedule | None:
    """The spec's StreamingSchedule (None unless ``missing="streaming"``)."""
    if spec.missing != "streaming":
        return None
    base = (fleet or build_fleet(spec)).modality_mask
    return streaming_schedule(base, spec.missing_ratio, spec.stream_period,
                              spec.seed)


@dataclasses.dataclass
class Scenario:
    """A fully-materialized spec: everything a runtime constructor takes."""
    spec: ScenarioSpec
    dataset: Any  # HARDataset-shaped (provider.build output)
    fleet: FleetConfig
    strategy: strategies.Strategy
    fed: Any  # AsyncFedConfig
    schedule: StreamingSchedule | None


def build_scenario(spec: ScenarioSpec, **fed_overrides) -> Scenario:
    from repro.core.async_engine import AsyncFedConfig

    provider = get_provider(spec.dataset)
    fleet = build_fleet(spec)
    ds = provider.build(seed=spec.seed, n_clients=fleet.N, alpha=spec.alpha,
                        windows_per_subject=spec.windows_per_subject)
    fed = AsyncFedConfig.from_scenario(spec, fleet=fleet, **fed_overrides)
    return Scenario(spec, ds, fleet, spec.build_strategy(), fed,
                    fed.modality_schedule)


def make_run(spec: ScenarioSpec, vectorized: bool = False,
             **fed_overrides):
    """Spec -> ready (run, Scenario). ``run.run(scenario.dataset)`` goes."""
    import jax

    from repro.core.async_engine import AsyncFedRun, VectorizedAsyncFedRun
    from repro.core.tasks import MMTask

    sc = build_scenario(spec, **fed_overrides)
    cfg = get_provider(spec.dataset).mm_config(spec.backbone,
                                               small=spec.small_model)
    task, tr0 = MMTask.create(cfg, jax.random.PRNGKey(spec.seed))
    cls = VectorizedAsyncFedRun if vectorized else AsyncFedRun
    run = cls.create(task, tr0, sc.strategy, sc.fleet, sc.fed)
    return run, sc


# ---------------------------------------------------------------------------
# scenario library (fed-multimodal-style sweep presets)
# ---------------------------------------------------------------------------

_LIB = [
    # the paper's coupled fleet, no extra missing generator
    ScenarioSpec("paper", missing="none"),
    # static masks at the fed-multimodal ratios on a full-possession fleet
    ScenarioSpec("static10", missing="static", missing_ratio=0.1),
    ScenarioSpec("static30", missing="static", missing_ratio=0.3),
    ScenarioSpec("static50", missing="static", missing_ratio=0.5),
    # tier-correlated missing (the paper's coupling, generator-driven)
    ScenarioSpec("tiered30", missing="tiered", missing_ratio=0.3),
    # time-varying streaming masks (arXiv:2505.16138-style online clients)
    ScenarioSpec("stream30", missing="streaming", missing_ratio=0.3),
    # audio+video two-modality scenario on the UCF101-style provider
    ScenarioSpec("ucf101_static30", dataset="ucf101_av", missing="static",
                 missing_ratio=0.3, fleet=(6, 6, 4)),
]
SCENARIOS = {s.name: s for s in _LIB}


def get_scenario(name: str, **replace) -> ScenarioSpec:
    """Library preset by name, optionally with field overrides."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"known: {sorted(SCENARIOS)}")
    spec = SCENARIOS[name]
    return dataclasses.replace(spec, **replace) if replace else spec


def scenario_names() -> list[str]:
    return sorted(SCENARIOS)
