from repro.sim.devices import (DEVICE_PROFILES, DeviceProfile, FleetConfig,
                               make_fleet, scale_fleet)
from repro.sim.faults import CORRUPTIONS, FaultModel, FaultRuntime
from repro.sim.fleet import (FleetState, PopulationModel, pack_group_bits,
                             unpack_group_bits)
from repro.sim.scenarios import (MISSING_GENERATORS, SCENARIOS, Scenario,
                                 ScenarioSpec, StreamingSchedule,
                                 build_fleet, build_scenario, get_scenario,
                                 make_run, scenario_names,
                                 static_missing_mask, streaming_schedule,
                                 tiered_missing_mask)
from repro.sim.timing import RoundCost, cycle_times, simulate_round
