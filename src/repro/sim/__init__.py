from repro.sim.devices import (DEVICE_PROFILES, DeviceProfile, FleetConfig,
                               make_fleet, scale_fleet)
from repro.sim.timing import RoundCost, simulate_round
