"""FLOP-proportional round timing + datasheet energy model (paper VI-A3).

Round anatomy per device n (synchronous FL):
    t_compute(n) = train_flops(n) / (tops_n * util)
    t_comm(n)    = upload_bytes(n) / bandwidth_n
    t_idle(n)    = round_time - t_compute(n) - t_comm(n)
    round_time   = max_n (t_compute + t_comm) + t_overhead

train_flops(n) charges only the parameter groups the device actually trains
(elastic masking saves backward+optimizer FLOPs; the frozen-forward cost is
charged always — this reproduces the paper's Sec. VII finding that LoRA
speedups are bounded by the fixed forward cost).

Energy per device = P_active*t_compute + P_comm*t_comm + P_idle*t_idle,
fleet energy = sum over devices (Eq. analog of Fig. 8).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.devices import FleetConfig


@dataclasses.dataclass
class RoundCost:
    round_time_s: float
    per_device_compute_s: np.ndarray
    per_device_comm_s: np.ndarray
    per_device_idle_s: np.ndarray
    fleet_energy_j: float
    upload_mb: float

    def as_dict(self) -> dict:
        return {"round_time_s": self.round_time_s,
                "fleet_energy_j": self.fleet_energy_j,
                "upload_mb": self.upload_mb}


def per_client_times(fleet: FleetConfig, trained_flops: np.ndarray,
                     fixed_flops: np.ndarray, upload_bytes: np.ndarray,
                     utilization: float = 0.3
                     ) -> tuple[np.ndarray, np.ndarray]:
    """[N] (t_compute, t_comm) for one local-training + upload cycle.

    Shared by the synchronous round simulator below and the event-driven
    runtime (sim/events.py), so sync and async results are comparable under
    the identical device model."""
    eff = fleet.tops * 1e12 * utilization
    t_comp = (np.asarray(trained_flops, np.float64)
              + np.asarray(fixed_flops, np.float64)) / eff
    t_comm = (np.asarray(upload_bytes, np.float64) * 8.0
              / (fleet.bandwidth_mbps * 1e6))
    return t_comp, t_comm


def cycle_times(fleet: FleetConfig, idx: np.ndarray,
                trained_flops: np.ndarray, fixed_flops: np.ndarray,
                upload_bytes: np.ndarray, t_overhead: float,
                utilization: float, jitter_sigma: float = 0.0,
                rng: np.random.Generator | None = None
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched (dispatch -> completion) cycle draw for clients ``idx``.

    Same arithmetic as ``per_client_times`` on ``fleet.subset(idx)`` but
    indexing the fleet arrays directly — no FleetConfig copy, so the
    vectorized runtime can draw for a million-client initial dispatch or a
    two-client redispatch at the same per-element cost.
    -> (duration, t_comp, t_comm), duration = comp + comm + overhead.
    """
    idx = np.asarray(idx)
    eff = fleet.tops[idx] * 1e12 * utilization
    t_comp = (np.asarray(trained_flops, np.float64)
              + np.asarray(fixed_flops, np.float64)) / eff
    t_comm = (np.asarray(upload_bytes, np.float64) * 8.0
              / (fleet.bandwidth_mbps[idx] * 1e6))
    if jitter_sigma > 0.0 and rng is not None:
        t_comp = t_comp * rng.lognormal(0.0, jitter_sigma, size=t_comp.shape)
    return t_comp + t_comm + t_overhead, t_comp, t_comm


def simulate_round(fleet: FleetConfig, selected: np.ndarray,
                   trained_flops: np.ndarray, fixed_flops: np.ndarray,
                   upload_bytes: np.ndarray, t_overhead: float = 0.05,
                   utilization: float = 0.3) -> RoundCost:
    """selected: [N] bool participation; trained_flops/fixed_flops: [N]
    per-round FLOPs for (masked backward+update) and (always-paid forward);
    upload_bytes: [N] Eq. 8 on-demand volume."""
    sel = np.asarray(selected, bool)
    t_comp, t_comm = per_client_times(fleet, trained_flops, fixed_flops,
                                      upload_bytes, utilization)
    t_comp = np.where(sel, t_comp, 0.0)
    t_comm = np.where(sel, t_comm, 0.0)
    busy = t_comp + t_comm
    round_time = float(busy.max()) + t_overhead if sel.any() else t_overhead
    t_idle = np.where(sel, round_time - busy, 0.0)
    energy = float(np.sum(np.where(
        sel,
        fleet.active_power * t_comp + fleet.comm_power * t_comm
        + fleet.idle_power * t_idle, 0.0)))
    return RoundCost(round_time, t_comp, t_comm, t_idle, energy,
                     float(upload_bytes[sel].sum()) / 1e6)


def group_train_flops(group_flops: np.ndarray, S: np.ndarray,
                      steps_per_round: int, flops_per_param: float = 4.0
                      ) -> np.ndarray:
    """[G] per-group cost x [N, G] selection -> [N] masked training FLOPs.

    flops_per_param ~ backward(2x) + optimizer(2x) per trained parameter per
    example-step; the forward cost goes into ``fixed_flops``.
    """
    return (S.astype(np.float64) @ group_flops) * steps_per_round * flops_per_param


def profile_tau(fleet: FleetConfig, group_flops: np.ndarray,
                steps_per_round: int, utilization: float = 0.3) -> np.ndarray:
    """Eq. 7's profiled per-group training time tau_n (uniform mean over
    groups, as in the paper)."""
    mean_group = float(np.mean(group_flops)) * steps_per_round * 4.0
    return mean_group / (fleet.tops * 1e12 * utilization)
