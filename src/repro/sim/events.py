"""Event-driven simulation primitives for the asynchronous federated runtime.

The synchronous engine advances time one barrier per round; here time is a
priority queue of client-completion events. Each client cycles

    dispatch (pull model v) -> local compute -> upload -> COMPLETION event

with (compute + comm) duration drawn from the same FLOP-proportional device
model as the synchronous simulator (sim/timing.py:per_client_times), plus an
optional lognormal jitter for non-deterministic system noise. Ties in
completion time (homogeneous fleets) are broken by push order, so event
processing is fully deterministic for a fixed seed — this is what makes the
sync-parity test bit-for-bit reproducible.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections.abc import Iterator
from typing import Any

import numpy as np

from repro.sim.devices import FleetConfig
from repro.sim.timing import per_client_times

COMPLETION = "completion"


@dataclasses.dataclass(frozen=True)
class Event:
    """One scheduled occurrence. Ordering: (time, seq) — seq is the queue's
    monotone push counter, so equal-time events pop FIFO."""
    time: float
    seq: int
    client: int
    kind: str = COMPLETION
    payload: Any = None


class EventQueue:
    """Deterministic min-heap of Events keyed by (time, push order)."""

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = 0

    def push(self, time: float, client: int, kind: str = COMPLETION,
             payload: Any = None) -> Event:
        ev = Event(float(time), self._seq, int(client), kind, payload)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._seq += 1
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[2]

    def peek_time(self) -> float:
        return self._heap[0][0]

    def pop_simultaneous(self) -> list[Event]:
        """Pop every event sharing the current minimum time (FIFO within the
        tie). Simultaneous completions are batched so the runtime can stack
        them through one vmapped local-update call."""
        if not self._heap:
            return []
        t0 = self.peek_time()
        out = [self.pop()]
        while self._heap and self.peek_time() == t0:
            out.append(self.pop())
        return out

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def drain(self) -> Iterator[Event]:
        while self._heap:
            yield self.pop()


def completion_times(fleet: FleetConfig, clients: np.ndarray,
                     trained_flops: np.ndarray, fixed_flops: np.ndarray,
                     upload_bytes: np.ndarray, t_overhead: float,
                     utilization: float,
                     jitter_sigma: float = 0.0,
                     rng: np.random.Generator | None = None
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Cycle durations for a dispatched subset of clients.

    clients: [K] fleet indices; trained/fixed/upload: [K] aligned with it.
    -> (duration [K], t_comp [K], t_comm [K]); duration includes the
    per-interaction server overhead. jitter_sigma > 0 multiplies compute by
    lognormal(0, sigma) noise (mean ~1), modelling OS/thermal variance.
    """
    sub = fleet.subset(clients)
    t_comp, t_comm = per_client_times(sub, trained_flops, fixed_flops,
                                      upload_bytes, utilization)
    if jitter_sigma > 0.0 and rng is not None:
        t_comp = t_comp * rng.lognormal(0.0, jitter_sigma, size=t_comp.shape)
    return t_comp + t_comm + t_overhead, t_comp, t_comm


@dataclasses.dataclass
class AsyncTrace:
    """Running account of the simulated execution (async analog of
    timing.RoundCost, but cumulative: there is no round to amortize over)."""
    sim_time: float = 0.0
    completions: int = 0
    flushes: int = 0
    energy_j: float = 0.0
    upload_mb: float = 0.0
    per_client_updates: np.ndarray | None = None

    def init_fleet(self, n: int) -> None:
        self.per_client_updates = np.zeros(n, np.int64)

    def record_completion(self, fleet: FleetConfig, client: int,
                          t_comp: float, t_comm: float,
                          upload_bytes: float) -> None:
        self.completions += 1
        self.energy_j += (fleet.active_power[client] * t_comp
                          + fleet.comm_power[client] * t_comm)
        self.upload_mb += upload_bytes / 1e6
        if self.per_client_updates is not None:
            self.per_client_updates[client] += 1

    def record_completions(self, fleet: FleetConfig, clients: np.ndarray,
                           t_comp: np.ndarray, t_comm: np.ndarray,
                           upload_bytes: np.ndarray) -> None:
        """Vectorized ``record_completion`` over a completion batch (the
        structure-of-arrays runtime absorbs whole timestamp groups)."""
        clients = np.asarray(clients)
        self.completions += int(clients.size)
        self.energy_j += float(
            np.sum(fleet.active_power[clients] * t_comp
                   + fleet.comm_power[clients] * t_comm))
        self.upload_mb += float(np.sum(upload_bytes)) / 1e6
        if self.per_client_updates is not None:
            np.add.at(self.per_client_updates, clients, 1)

    def as_dict(self) -> dict:
        return {"sim_time_s": self.sim_time, "completions": self.completions,
                "flushes": self.flushes, "energy_j": self.energy_j,
                "upload_mb": self.upload_mb}
