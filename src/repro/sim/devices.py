"""Device profiles and fleet construction (paper Sec. VI-A3 + VII).

FLOP-proportional timing calibrated to edge TOPS; power from Jetson AGX Orin
datasheet modes. The paper's three device types couple modality count with
compute (the "device cost gradient"):

    Full      4 modalities, 275 TOPS (AGX Orin MAXN, 60 W)
    Mid       2 modalities,  21 TOPS (Xavier NX, 30 W mode -> 30 W)
    Low       1 modality,     5 TOPS (low-end IoT, 15 W mode -> 5..15 W)

Heterogeneity scales (10x / 55x / 100x, Tables IV-V) rescale the Mid/Low
compute relative to Full.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    tops: float  # effective trillion ops/s
    active_power_w: float
    comm_power_w: float
    idle_frac: float = 0.2  # idle power = 20% of active (paper VI-A3)
    bandwidth_mbps: float = 100.0  # uplink

    @property
    def idle_power_w(self) -> float:
        return self.idle_frac * self.active_power_w


DEVICE_PROFILES = {
    "full": DeviceProfile("full", 275.0, 60.0, 10.0),
    "mid": DeviceProfile("mid", 21.0, 30.0, 8.0),
    "low": DeviceProfile("low", 5.0, 15.0, 5.0),
    # real-device testbed analogues (Sec. VII, Jetson power modes)
    "orin_maxn": DeviceProfile("orin_maxn", 275.0, 60.0, 10.0),
    "orin_30w": DeviceProfile("orin_30w", 92.0, 30.0, 8.0),
    "orin_15w": DeviceProfile("orin_15w", 40.0, 15.0, 6.0),
}


@dataclasses.dataclass
class FleetConfig:
    """N devices with coupled system-modality heterogeneity."""
    modality_mask: np.ndarray  # [N, M] bool
    tops: np.ndarray  # [N]
    active_power: np.ndarray  # [N] W
    comm_power: np.ndarray  # [N] W
    idle_power: np.ndarray  # [N] W
    bandwidth_mbps: np.ndarray  # [N]
    type_names: list[str]  # immutable after construction (cached below)
    # lazily-built str array mirror of type_names, so repeated subset() calls
    # (one per event-loop dispatch) fancy-index instead of list-comprehending.
    # Built once on first use — mutating type_names afterwards is unsupported
    # (a length heuristic would miss same-length in-place replacement).
    _names_arr: np.ndarray | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def N(self) -> int:
        return len(self.tops)

    @property
    def M(self) -> int:
        return self.modality_mask.shape[1]

    def names_array(self) -> np.ndarray:
        if self._names_arr is None:
            self._names_arr = np.asarray(self.type_names)
        return self._names_arr

    def subset(self, idx) -> FleetConfig:
        """Fleet restricted to client indices ``idx`` (sliced arrays; names
        via the cached string array, not a per-call list comprehension)."""
        idx = np.asarray(idx)
        return FleetConfig(self.modality_mask[idx], self.tops[idx],
                           self.active_power[idx], self.comm_power[idx],
                           self.idle_power[idx], self.bandwidth_mbps[idx],
                           self.names_array()[idx].tolist())

    @classmethod
    def from_scenario(cls, spec) -> FleetConfig:
        """Build the fleet a :class:`repro.sim.scenarios.ScenarioSpec`
        describes (tier counts, hetero scale, missing-modality generator)."""
        from repro.sim.scenarios import build_fleet  # avoid import cycle

        return build_fleet(spec)


def make_fleet(n_full: int, n_mid: int, n_low: int, M: int = 4,
               mid_modalities: tuple[int, ...] = (0, 1),
               low_modalities: tuple[int, ...] = (0,),
               hetero_scale: float | None = None) -> FleetConfig:
    """Paper fleets: PAMAP2 = (3,3,2), MHEALTH = (3,3,4).

    hetero_scale: compute gap Full/Low (10/55/100); None = profile defaults
    (275/5 = 55x, the paper's "Moderate").
    """
    rows = ([("full", tuple(range(M)))] * n_full +
            [("mid", mid_modalities)] * n_mid +
            [("low", low_modalities)] * n_low)
    N = len(rows)
    mask = np.zeros((N, M), bool)
    tops = np.zeros(N)
    pa = np.zeros(N)
    pc = np.zeros(N)
    pi = np.zeros(N)
    bw = np.zeros(N)
    names = []
    for i, (ty, mods) in enumerate(rows):
        prof = DEVICE_PROFILES[ty]
        mask[i, list(mods)] = True
        t = prof.tops
        if hetero_scale is not None and ty != "full":
            base = DEVICE_PROFILES["full"].tops
            # keep the paper's mid/low ratio but rescale the full/low gap
            rel = {"mid": 21.0 / 5.0, "low": 1.0}[ty]
            t = base / hetero_scale * rel
        tops[i] = t
        pa[i], pc[i], pi[i] = prof.active_power_w, prof.comm_power_w, prof.idle_power_w
        bw[i] = prof.bandwidth_mbps
        names.append(ty)
    return FleetConfig(mask, tops, pa, pc, pi, bw, names)


def scale_fleet(fleet: FleetConfig, n_clients: int,
                rng: np.random.Generator) -> FleetConfig:
    """Tables IV-V fleet-size sweep: replicate the type mixture to N."""
    idx = rng.integers(0, fleet.N, size=n_clients)
    return fleet.subset(idx)
