"""Fleet fault injection: dropout, stalls, and Byzantine delta corruption.

Real IoT fleets drop, stall, and lie — and RELIEF's cohort-wise aggregation
(paper Eq. 3) makes rare-modality cohorts *small by construction*, so a
single corrupted client can dominate an entire modality block. This module
is the attack side of that story: a composable ``FaultModel`` consumed by
both async runtimes (core/async_engine.py), with the robust within-cohort
reducers in core/aggregation.py as the defence.

Fault channels (all optional, all applied only to the *faulty population*
selected by ``byzantine_frac`` / ``target_modality``):

    dropout      the cycle's completion never reaches the server: no energy
                 is accrued, nothing is buffered, the client is simply
                 redispatched at the time the completion would have fired
                 (a mid-round crash + reboot)
    stall        the cycle's compute time is multiplied by ``stall_factor``
                 (thermal throttling / contention); energy scales with it
    corruption   the uploaded delta is replaced before the (optional) int8
                 uplink quantization:
                   sign_flip   d -> -scale * d        (gradient inversion)
                   gauss       d -> d + scale * N(0,I) (blow-up noise)
                   collusion   d -> scale * u          (all Byzantine clients
                               push one shared pseudo-random direction u)

Determinism: Byzantine membership is a pure function of (seed, fleet);
per-cycle draws are keyed by (seed, client, dispatch ticket) — counter-based
like the cohort-mode batch draws — so fault realizations are independent of
event interleaving and the heap / vectorized runtimes stay history-
equivalent under an identical ``FaultModel`` (tested in tests/test_fleet.py).

Per-cohort targeting: ``target_modality = m`` restricts the Byzantine set
to clients *possessing* modality m, concentrating the attack inside that
modality's aggregation cohort — the configuration that breaks plain-mean
cohort aggregation at the smallest global attacker budget.

Caveat: ``dropout_prob = 1.0`` with ``byzantine_frac = 1.0`` never absorbs
a completion — the run cannot terminate. Keep some honest clients.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

CORRUPTIONS = ("none", "sign_flip", "gauss", "collusion")

# rng stream salts — distinct sub-streams of the model seed
_BYZ_SALT = 0xB12A
_CYCLE_SALT = 0xFA017
_GAUSS_SALT = 0x6A55
_COLLUDE_SALT = 0xC011


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Declarative fault/attack configuration (hangs off AsyncFedConfig)."""
    seed: int = 0
    byzantine_frac: float = 0.0  # fraction of the candidate set that faults
    corruption: str = "sign_flip"  # none | sign_flip | gauss | collusion
    corruption_scale: float = 10.0
    dropout_prob: float = 0.0  # P(cycle's completion is lost), per cycle
    stall_prob: float = 0.0  # P(cycle is stalled), per cycle
    stall_factor: float = 10.0  # compute-time multiplier when stalled
    target_modality: int | None = None  # restrict faults to possessors of m

    def __post_init__(self):
        if self.corruption not in CORRUPTIONS:
            raise ValueError(f"corruption must be one of {CORRUPTIONS}, "
                             f"got {self.corruption!r}")
        if not 0.0 <= self.byzantine_frac <= 1.0:
            raise ValueError("byzantine_frac must be in [0, 1]")

    @property
    def active(self) -> bool:
        return self.byzantine_frac > 0.0

    # -- membership -----------------------------------------------------------

    def byzantine_mask(self, modality_mask: np.ndarray) -> np.ndarray:
        """[N, M] possession -> [N] bool faulty membership.

        A seeded permutation of the candidate set (possessors of
        ``target_modality``, or the whole fleet) takes the first
        round(byzantine_frac * n_candidates) clients — deterministic in
        (seed, fleet) and independent of runtime event order.
        """
        mm = np.asarray(modality_mask, bool)
        byz = np.zeros(mm.shape[0], bool)
        if self.byzantine_frac <= 0.0:
            return byz
        if self.target_modality is not None:
            cand = np.nonzero(mm[:, self.target_modality])[0]
        else:
            cand = np.arange(mm.shape[0])
        n_byz = int(round(self.byzantine_frac * len(cand)))
        rng = np.random.default_rng([self.seed, _BYZ_SALT])
        byz[rng.permutation(cand)[:n_byz]] = True
        return byz

    # -- per-cycle system faults ----------------------------------------------

    def cycle_faults(self, byz: np.ndarray, clients: np.ndarray,
                     tickets: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """-> (dropped [B] bool, slowdown [B] float) for one dispatch batch.

        Draws are keyed by (seed, client, ticket) so a cycle's fate is a
        pure function of *which* cycle it is, not of when the runtime
        happens to simulate it.
        """
        B = len(clients)
        dropped = np.zeros(B, bool)
        slow = np.ones(B)
        if self.dropout_prob <= 0.0 and self.stall_prob <= 0.0:
            return dropped, slow
        for i in np.nonzero(byz[clients])[0]:
            r = np.random.default_rng(
                [self.seed, _CYCLE_SALT, int(clients[i]), int(tickets[i])])
            u_drop, u_stall = r.random(2)
            dropped[i] = u_drop < self.dropout_prob
            if u_stall < self.stall_prob:
                slow[i] = self.stall_factor
        return dropped, slow

    # -- delta corruption -----------------------------------------------------

    def _collusion_direction(self, np_leaves: list[np.ndarray]) -> list:
        """The shared attack direction u: one pseudo-random draw per leaf
        shape, identical for every colluder and every cycle."""
        rng = np.random.default_rng([self.seed, _COLLUDE_SALT])
        return [rng.standard_normal(x.shape[1:]).astype(np.float32)
                for x in np_leaves]

    def corrupt_stack(self, deltas: Any, byz_rows: np.ndarray,
                      clients: np.ndarray, tickets: np.ndarray) -> Any:
        """Corrupt the Byzantine rows of a client-stacked delta pytree.

        deltas: [B, ...] leaves (fp32, pre-quantization); byz_rows: [B]
        bool; clients/tickets: [B] draw keys. Gaussian noise is drawn per
        (seed, client, ticket) sequentially over the flattened leaf order,
        so any two callers corrupting the same cycle of the same client
        produce bit-identical payloads regardless of batch composition.
        """
        if self.corruption == "none":
            return deltas
        rows = np.nonzero(np.asarray(byz_rows, bool))[0]
        if len(rows) == 0:
            return deltas
        leaves, treedef = jax.tree_util.tree_flatten(deltas)
        out = [np.array(x, np.float32) for x in leaves]
        c = self.corruption_scale
        if self.corruption == "sign_flip":
            for x in out:
                x[rows] = -c * x[rows]
        elif self.corruption == "gauss":
            for i in rows:
                rng = np.random.default_rng(
                    [self.seed, _GAUSS_SALT, int(clients[i]),
                     int(tickets[i])])
                for x in out:
                    x[i] = x[i] + c * rng.standard_normal(
                        x.shape[1:]).astype(np.float32)
        else:  # collusion
            u = self._collusion_direction(out)
            for x, d in zip(out, u):
                x[rows] = c * d
        return jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(x) for x in out])


class FaultRuntime:
    """Per-run fault-injection state shared by both async runtimes: the
    resolved Byzantine membership and the per-client dispatch ticket counter
    that keys the counter-based fault draws."""

    def __init__(self, model: FaultModel, modality_mask: np.ndarray):
        self.model = model
        self.byz = model.byzantine_mask(modality_mask)
        self.tickets = np.zeros(len(self.byz), np.int64)

    def on_dispatch(self, clients: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray]:
        """Advance the dispatch tickets for ``clients`` and draw this
        cycle's faults -> (dropped [B], slowdown [B], byz_rows [B],
        tickets [B])."""
        t = self.tickets[clients].copy()
        self.tickets[clients] += 1
        dropped, slow = self.model.cycle_faults(self.byz, clients, t)
        return dropped, slow, self.byz[clients], t

    def corrupt(self, deltas: Any, byz_rows: np.ndarray, clients: np.ndarray,
                tickets: np.ndarray) -> Any:
        return self.model.corrupt_stack(deltas, byz_rows, clients, tickets)
