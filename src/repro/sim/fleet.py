"""Structure-of-arrays fleet state for million-client async simulation.

The event-driven runtime (core/async_engine.py + sim/events.py) keeps one
Python ``_Pending`` object per in-flight client and one heap entry per
completion — fine at N~100, hopeless at the ROADMAP's 10^5-10^6 clients.
Here every piece of per-client system state lives in a flat NumPy array
indexed by client id:

    t_next       [N] next completion time (+inf = idle or departed)
    seq          [N] dispatch counter at the last dispatch — replays the
                 event queue's FIFO tie-break exactly (equal times pop in
                 dispatch order), so the vectorized runtime reproduces the
                 heap-based loop event for event
    version      [N] server model version pulled at dispatch
    group_bits   [N] trained-group selection packed into a uint64 bitmask
    t_comp/t_comm/upload_bytes
                 [N] the in-flight cycle's cost split
    energy_j / updates
                 [N] cumulative per-client account (the SoA analog of
                 AsyncTrace.per_client_updates)
    alive        [N] population membership (churn model below)
    lost         [N] the client's pending completion was cancelled by a
                 departure — claimed-but-unabsorbed events can't be told
                 apart from idle by ``t_next`` alone, so cancellation is
                 tracked explicitly; only the next dispatch clears it
                 (re-arrival does NOT resurrect a lost update)

Event extraction replaces the heap with ``peek_window``: one
``np.partition`` pass finds the k-th smallest completion time, one threshold
scan collects every event at or below it (so FIFO tie groups are never
split), and the window is truncated to events provably unaffected by
redispatches of earlier events in the same window — a redispatched client
cannot complete sooner than ``gap`` (the per-cycle server overhead) after
its completion, so every event strictly inside ``[t0, t0 + gap)`` is safe
to process in one batch. With ``gap = 0`` this degenerates to the exact
``pop_simultaneous`` semantics of the heap loop.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.devices import FleetConfig

_EMPTY = np.empty(0, np.int64)


def pack_group_bits(S: np.ndarray) -> np.ndarray:
    """[B, G] bool selection -> [B] uint64 bitmask (bit g = group g)."""
    S = np.asarray(S, bool)
    G = S.shape[1]
    if G > 64:
        raise ValueError(f"group bitmask supports G <= 64, got G={G}")
    weights = np.uint64(1) << np.arange(G, dtype=np.uint64)
    return (S.astype(np.uint64) * weights[None, :]).sum(1, dtype=np.uint64)


def unpack_group_bits(bits: np.ndarray, G: int) -> np.ndarray:
    """[B] uint64 bitmask -> [B, G] bool selection."""
    weights = np.uint64(1) << np.arange(G, dtype=np.uint64)
    return (np.asarray(bits)[:, None] & weights[None, :]) != 0


@dataclasses.dataclass
class FleetState:
    """Flat per-client arrays for the vectorized async runtime."""
    t_next: np.ndarray  # [N] float64, +inf = no event scheduled
    seq: np.ndarray  # [N] int64 dispatch order (FIFO tie-break)
    version: np.ndarray  # [N] int64 pulled server version
    group_bits: np.ndarray  # [N] uint64 uploaded-group bitmask
    mod_bits: np.ndarray  # [N] uint64 live modality mask at dispatch
    t_comp: np.ndarray  # [N] in-flight compute seconds
    t_comm: np.ndarray  # [N] in-flight comm seconds
    upload_bytes: np.ndarray  # [N] in-flight upload volume
    energy_j: np.ndarray  # [N] cumulative energy
    updates: np.ndarray  # [N] int64 cumulative completions
    alive: np.ndarray  # [N] bool population membership
    lost: np.ndarray  # [N] bool pending completion cancelled by departure
    next_seq: int = 0
    in_flight: int = 0

    @classmethod
    def create(cls, n: int) -> FleetState:
        return cls(t_next=np.full(n, np.inf),
                   seq=np.zeros(n, np.int64),
                   version=np.zeros(n, np.int64),
                   group_bits=np.zeros(n, np.uint64),
                   mod_bits=np.zeros(n, np.uint64),
                   t_comp=np.zeros(n), t_comm=np.zeros(n),
                   upload_bytes=np.zeros(n), energy_j=np.zeros(n),
                   updates=np.zeros(n, np.int64),
                   alive=np.ones(n, bool),
                   lost=np.zeros(n, bool))

    @property
    def N(self) -> int:
        return self.t_next.shape[0]

    # -- scheduling -----------------------------------------------------------

    def dispatch(self, idx: np.ndarray, now: float, version: int,
                 bits: np.ndarray, dur: np.ndarray, t_comp: np.ndarray,
                 t_comm: np.ndarray, upload_bytes: np.ndarray) -> None:
        """Schedule completion events for (idle) clients ``idx``. ``idx``
        order defines the FIFO tie-break, matching EventQueue push order."""
        b = len(idx)
        if b == 0:
            return
        self.t_next[idx] = now + dur
        self.seq[idx] = np.arange(self.next_seq, self.next_seq + b)
        self.next_seq += b
        self.version[idx] = version
        self.group_bits[idx] = bits
        self.t_comp[idx] = t_comp
        self.t_comm[idx] = t_comm
        self.upload_bytes[idx] = upload_bytes
        self.lost[idx] = False
        self.in_flight += b

    def peek_window(self, k: int, gap: float
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Next-k extraction: -> (times, client idx), sorted by (time, seq).

        Includes every tie of the k-th smallest time (FIFO groups are never
        split) and truncates to events < t0 + ``gap`` — the earliest instant
        a redispatch of this window's first event could complete — so batch
        processing is order-identical to popping the heap one event at a
        time. Does not consume the events; call ``claim`` on (a prefix of)
        the returned indices."""
        t = self.t_next
        if self.in_flight == 0:
            return np.empty(0), _EMPTY
        k = min(max(k, 1), t.shape[0])
        kth = np.partition(t, k - 1)[k - 1]
        if np.isinf(kth):
            idx = np.nonzero(np.isfinite(t))[0]
        else:
            idx = np.nonzero(t <= kth)[0]
        idx = idx[np.lexsort((self.seq[idx], t[idx]))]
        times = t[idx]
        t0 = times[0]
        if gap > 0.0:
            cut = int(np.searchsorted(times, t0 + gap, side="left"))
        else:
            cut = int(np.searchsorted(times, t0, side="right"))
        return times[:cut].copy(), idx[:cut]

    def claim(self, idx: np.ndarray) -> None:
        """Consume scheduled events (the completions are being processed)."""
        self.t_next[idx] = np.inf
        self.in_flight -= len(idx)

    def complete(self, fleet: FleetConfig, idx: np.ndarray) -> None:
        """Accrue the finished cycle's energy/updates for clients ``idx``."""
        self.energy_j[idx] += (fleet.active_power[idx] * self.t_comp[idx]
                               + fleet.comm_power[idx] * self.t_comm[idx])
        self.updates[idx] += 1

    # -- population membership ------------------------------------------------

    def depart(self, idx: np.ndarray) -> None:
        """Remove clients from the population: any in-flight work is lost
        and they stop accruing energy/updates until they re-arrive. ``lost``
        marks the cancelled completion so a claimed-but-unabsorbed event is
        dropped at absorb time even if the client re-arrives first."""
        if len(idx) == 0:
            return
        self.in_flight -= int(np.isfinite(self.t_next[idx]).sum())
        self.t_next[idx] = np.inf
        self.alive[idx] = False
        self.lost[idx] = True

    def arrive(self, idx: np.ndarray) -> None:
        """Re-admit departed clients (idle until the runtime dispatches;
        ``lost`` stays set — a cancelled completion is never resurrected)."""
        self.alive[idx] = True


@dataclasses.dataclass(frozen=True)
class PopulationModel:
    """Memoryless arrivals/churn over the fleet population.

    Between consecutive event timestamps (dt apart), each alive client
    departs with probability 1 - exp(-churn_rate * dt) and each departed
    client re-arrives with probability 1 - exp(-arrival_rate * dt) —
    i.e. exponential sojourn times in both states. Departing in-flight
    clients lose their update (the completion never fires)."""
    churn_rate: float = 0.0  # departures per alive client per sim-second
    arrival_rate: float = 0.0  # re-arrivals per departed client per sim-sec

    def step(self, rng: np.random.Generator, state: FleetState, dt: float
             ) -> tuple[np.ndarray, np.ndarray]:
        """Advance membership by ``dt`` -> (departed idx, arrived idx)."""
        departed, arrived = _EMPTY, _EMPTY
        if dt <= 0.0:
            return departed, arrived
        if self.churn_rate > 0.0:
            p = -np.expm1(-self.churn_rate * dt)
            alive = np.nonzero(state.alive)[0]
            departed = alive[rng.random(alive.size) < p]
            state.depart(departed)
        if self.arrival_rate > 0.0:
            p = -np.expm1(-self.arrival_rate * dt)
            gone = np.nonzero(~state.alive)[0]
            arrived = gone[rng.random(gone.size) < p]
            state.arrive(arrived)
        return departed, arrived
