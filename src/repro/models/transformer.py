"""Decoder-only transformer LM (dense / MoE / VLM / audio variants).

One config-driven implementation covers phi3-medium, gemma2 (alternating
local/global + softcaps + post-norms), granite (GQA/MQA), llava-next (vision
patch embeddings prepended — frontend stub), musicgen (parallel codebook
streams) and mixtral (MoE MLP, sliding window).

Layers are consumed with ``jax.lax.scan`` over stacked parameters so the HLO
(and compile time on the 512-device dry-run) stays O(1) in depth.  The layer
pattern (uniform vs gemma-2 alternating local/global) is expressed as
``n_sub`` sublayers per scan step with per-sublayer window sizes, so a single
scan handles every pattern.
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE

Array = jax.Array
GLOBAL_WINDOW = np.iinfo(np.int32).max


def pattern(cfg: ModelConfig) -> tuple[int, tuple[int, ...]]:
    """-> (n_sub, per-sublayer window sizes in tokens)."""
    if cfg.layer_pattern == "alternating":
        return 2, (cfg.sliding_window, GLOBAL_WINDOW)
    if cfg.layer_pattern == "local":
        return 1, (cfg.sliding_window,)
    return 1, (GLOBAL_WINDOW,)


def attn_dims(cfg: ModelConfig) -> L.AttnDims:
    return L.AttnDims(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key: Array, cfg: ModelConfig) -> dict:
    ka, km = jax.random.split(key)
    dt = cfg.p_dtype()
    p: dict[str, Any] = {
        "attn": L.init_attention(ka, attn_dims(cfg), dt),
        "ln1": L.init_rmsnorm(cfg.d_model, dt),
        "ln2": L.init_rmsnorm(cfg.d_model, dt),
    }
    if cfg.family == "moe":
        p["mlp"] = MOE.init_moe_mlp(km, cfg.d_model, cfg.d_ff, cfg.n_experts, dt)
    else:
        p["mlp"] = L.init_glu_mlp(km, cfg.d_model, cfg.d_ff, dt)
    if cfg.post_norms:  # gemma-2 style post-attention/post-ffw norms
        p["ln1b"] = L.init_rmsnorm(cfg.d_model, dt)
        p["ln2b"] = L.init_rmsnorm(cfg.d_model, dt)
    return p


def init_lora(key: Array, cfg: ModelConfig) -> dict:
    """LoRA adapters for the attention projections of every layer.

    Storage convention: y += (x @ a) @ b * (alpha/rank); a: [in, r], b: [r, out].
    ``wo``'s *a* matrix ([n_heads*head_dim, r]) is the fusion-projection whose
    input concatenates per-head (per-modality for hybrid archs) features — the
    RELIEF block axis (see core/mdlora.py).
    """
    dt = jnp.float32 if cfg.lora_dtype == "float32" else cfg.p_dtype()
    r = cfg.lora_rank
    d, hhd = cfg.d_model, cfg.n_heads * cfg.head_dim
    khd = cfg.n_kv_heads * cfg.head_dim
    shapes = {"wq": (d, hhd), "wk": (d, khd), "wv": (d, khd), "wo": (hhd, d)}

    def one_layer(k):
        out = {}
        for name, (din, dout) in shapes.items():
            if name not in cfg.lora_targets and not (
                    name == "wo" and "wo_fusion" in cfg.lora_targets):
                continue
            k, ka = jax.random.split(k)
            out[name] = {
                "a": (jax.random.normal(ka, (din, r)) / math.sqrt(din)).astype(dt),
                "b": jnp.zeros((r, dout), dtype=dt),
            }
        return out

    return jax.vmap(one_layer)(jax.random.split(key, cfg.n_layers))


def padded_vocab(cfg: ModelConfig) -> int:
    """Embedding tables are padded to a multiple of 128 (MXU lane width /
    TP-shardable) — standard production practice; logits are sliced back to
    the true vocab so the architecture semantics are exact."""
    v = cfg.vocab * max(cfg.n_codebooks, 1)
    return -(-v // 128) * 128


def init_lm(key: Array, cfg: ModelConfig, with_lora: bool = True) -> dict:
    ke, kl, kh, klo = jax.random.split(key, 4)
    dt = cfg.p_dtype()
    base: dict[str, Any] = {
        "embed": L.embed_init(ke, padded_vocab(cfg), cfg.d_model, dt),
        "layers": jax.vmap(lambda k: _init_layer(k, cfg))(
            jax.random.split(kl, cfg.n_layers)),
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        base["lm_head"] = L.dense_init(kh, cfg.d_model, padded_vocab(cfg), dt)
    params = {"base": base}
    if with_lora:
        params["lora"] = {"layers": init_lora(klo, cfg)}
    return params


# ---------------------------------------------------------------------------
# LoRA application
# ---------------------------------------------------------------------------


def lora_delta(lora_p: dict | None, name: str, x: Array, cfg: ModelConfig) -> Array | float:
    if lora_p is None or name not in lora_p:
        return 0.0
    a, b = lora_p[name]["a"], lora_p[name]["b"]
    scale = cfg.lora_alpha / cfg.lora_rank
    return (((x.astype(a.dtype) @ a) @ b) * scale).astype(x.dtype)


def _proj(base_w: Array, lora_p: dict | None, name: str, x: Array,
          cfg: ModelConfig, ctx: dict | None = None) -> Array:
    """Projection with LoRA. ``ctx`` carries the serving extensions:

    * ``adapter_idx`` [B] — multi-tenant decode: ``lora_p`` leaves are
      stacked [A, din, r] and each batch row applies its own adapter via
      the gathered ``mdlora_matmul_multi`` kernel (one fused call, no
      per-request weight copies). Requires S == 1 (decode).
    * ``fusion_mask`` [B, din] — RELIEF modality row mask over the fusion
      (``wo``) projection input; zeroes absent-modality blocks.
    * ``lora_impl`` — "xla" | "pallas" for the gathered kernel.
    """
    if ctx is not None and ctx.get("adapter_idx") is not None:
        from repro.kernels import mdlora as MD

        mask = ctx.get("fusion_mask") if name == "wo" else None
        if lora_p is not None and name in lora_p:
            y = MD.mdlora_matmul_multi(
                x[:, 0], base_w, lora_p[name]["a"], lora_p[name]["b"],
                ctx["adapter_idx"], row_mask=mask,
                scale=cfg.lora_alpha / cfg.lora_rank,
                impl=ctx.get("lora_impl", "xla"))
            return y[:, None].astype(x.dtype)
        if mask is not None:
            x = x * mask[:, None, :].astype(x.dtype)
        return x @ base_w
    if name == "wo" and ctx is not None and ctx.get("fusion_mask") is not None:
        x = x * ctx["fusion_mask"][:, None, :].astype(x.dtype)
    return x @ base_w + lora_delta(lora_p, name, x, cfg)


# ---------------------------------------------------------------------------
# transformer block (attention + MLP, with LoRA hooks)
# ---------------------------------------------------------------------------


def _cache_scatter(buf: Array, slots: Array, val: Array) -> Array:
    """Write new entries into a ring buffer [B, T, ...].

    slots [S] (shared positions) broadcasts over the batch; slots [B, S]
    (per-row positions, continuous batching) scatters each row at its own
    slot so requests mid-stream at different depths share one decode step.
    """
    if slots.ndim == 2:
        bidx = jnp.arange(buf.shape[0])[:, None]
        return buf.at[bidx, slots].set(val)
    return buf.at[:, slots].set(val)


def _pos_scatter(pos_buf: Array, slots: Array, positions: Array) -> Array:
    """Update the cache position leaf: [T] shared or [B, T] per-row.

    A per-row leaf written with shared 1-D positions (e.g. single-request
    prefill into a per-row cache) broadcasts over the batch axis.
    """
    if slots.ndim == 2:
        bidx = jnp.arange(pos_buf.shape[0])[:, None]
        return pos_buf.at[bidx, slots].set(positions)
    if pos_buf.ndim == 2:
        return pos_buf.at[:, slots].set(positions)
    return pos_buf.at[slots].set(positions)


def _attention_lora(p: dict, lp: dict | None, cfg: ModelConfig, x: Array,
                    positions: Array, kv_cache: dict | None, window,
                    ctx: dict | None = None) -> tuple:
    from repro.dist.sharding import act_hint

    dims = attn_dims(cfg)
    B, S, _ = x.shape
    H, K, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    q = act_hint(_proj(p["wq"], lp, "wq", x, cfg, ctx), "batch", None, "model")
    k = act_hint(_proj(p["wk"], lp, "wk", x, cfg, ctx), "batch", None, "model")
    v = act_hint(_proj(p["wv"], lp, "wv", x, cfg, ctx), "batch", None, "model")
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if cfg.query_scale is not None:
        q = q * (cfg.query_scale * math.sqrt(hd))

    new_cache = None
    if kv_cache is None:
        kk, vv, kv_pos = k, v, positions
        k_scale = v_scale = None
    else:
        T = kv_cache["k"].shape[1]
        slots = positions % T
        if "k_scale" in kv_cache:  # int8 KV cache, per-(token, head) scales
            ks = jnp.max(jnp.abs(k.astype(jnp.float32)), -1) / 127.0 + 1e-8
            vs = jnp.max(jnp.abs(v.astype(jnp.float32)), -1) / 127.0 + 1e-8
            k8 = jnp.round(k.astype(jnp.float32) / ks[..., None]
                           ).astype(jnp.int8)
            v8 = jnp.round(v.astype(jnp.float32) / vs[..., None]
                           ).astype(jnp.int8)
            kk = _cache_scatter(kv_cache["k"], slots, k8)
            vv = _cache_scatter(kv_cache["v"], slots, v8)
            k_scale = _cache_scatter(kv_cache["k_scale"], slots, ks)
            v_scale = _cache_scatter(kv_cache["v_scale"], slots, vs)
            kv_pos = _pos_scatter(kv_cache["pos"], slots, positions)
            new_cache = {"k": kk, "v": vv, "k_scale": k_scale,
                         "v_scale": v_scale, "pos": kv_pos}
        else:
            k_scale = v_scale = None
            kk = _cache_scatter(kv_cache["k"], slots,
                                k.astype(kv_cache["k"].dtype))
            vv = _cache_scatter(kv_cache["v"], slots,
                                v.astype(kv_cache["v"].dtype))
            kv_pos = _pos_scatter(kv_cache["pos"], slots, positions)
            new_cache = {"k": kk, "v": vv, "pos": kv_pos}
    if k_scale is not None:  # dequantize at use (transient, per layer)
        dt_ = cfg.runtime_dtype()
        kk = (kk.astype(jnp.float32) * k_scale[..., None]).astype(dt_)
        vv = (vv.astype(jnp.float32) * v_scale[..., None]).astype(dt_)

    if cfg.attn_impl == "pallas" and positions.ndim == 1 and kv_pos.ndim == 1:
        qg = q.reshape(B, S, K, H // K, hd)
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(qg, kk, vv, positions, kv_pos, window,
                                   cfg.attn_softcap)
    else:
        # XLA path: repeat KV to full heads and shard the HEAD axis over
        # ``model`` (Megatron TP; non-divisible head counts get GSPMD's
        # padded sharding — DESIGN.md §4). The cache stays grouped [.., K,
        # hd]; the repeat is a transient per layer.
        G = H // K
        kr = jnp.repeat(kk, G, axis=2) if G > 1 else kk
        vr = jnp.repeat(vv, G, axis=2) if G > 1 else vv
        qh = act_hint(q, "batch", None, "model_pad", None)
        kr = act_hint(kr, "batch", None, "model_pad", None)
        vr = act_hint(vr, "batch", None, "model_pad", None)
        o = L._chunked_attention(qh[:, :, :, None], kr, vr, positions,
                                 kv_pos, window, cfg.attn_softcap,
                                 cfg.q_chunk)
    o = act_hint(o.reshape(B, S, H * hd), "batch", None, "model")
    return _proj(p["wo"], lp, "wo", o, cfg, ctx), new_cache


def _sublayer(p: dict, lp: dict | None, cfg: ModelConfig, x: Array,
              positions: Array, cache: dict | None, window,
              ctx: dict | None = None) -> tuple:
    from repro.dist.sharding import act_hint

    seq_ax = "model" if cfg.seq_shard else None
    x = act_hint(x, "batch", seq_ax, None)  # residual (SP: seq-sharded)
    h = L.rmsnorm(p["ln1"], x)
    attn_out, new_cache = _attention_lora(p["attn"], lp, cfg, h, positions,
                                          cache, window, ctx)
    if cfg.post_norms:
        attn_out = L.rmsnorm(p["ln1b"], attn_out)
    attn_out = act_hint(attn_out, "batch", seq_ax, None)  # SP: reduce-scatter
    x = x + attn_out
    h = L.rmsnorm(p["ln2"], x)
    if cfg.family == "moe":
        mlp_out, aux = MOE.moe_mlp(p["mlp"], h, top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor,
                                   activation=cfg.activation,
                                   impl=cfg.moe_impl)
    else:
        hint = lambda t: act_hint(t, "batch", None, "model")
        mlp_out, aux = L.glu_mlp(p["mlp"], h, cfg.activation, hint), 0.0
    if cfg.post_norms:
        mlp_out = L.rmsnorm(p["ln2b"], mlp_out)
    mlp_out = act_hint(mlp_out, "batch", seq_ax, None)  # SP: reduce-scatter
    return x + mlp_out, new_cache, aux


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# embedding / unembedding (dense, vlm, audio variants)
# ---------------------------------------------------------------------------


def embed_tokens(params: dict, cfg: ModelConfig, tokens: Array,
                 patches: Array | None = None) -> Array:
    emb = params["base"]["embed"]
    if cfg.n_codebooks:  # musicgen: tokens [B, S, n_codebooks], summed streams
        offs = jnp.arange(cfg.n_codebooks, dtype=tokens.dtype) * cfg.vocab
        x = jnp.sum(jnp.take(emb, tokens + offs, axis=0), axis=2)
    else:
        x = jnp.take(emb, tokens, axis=0)
    x = x.astype(cfg.runtime_dtype())
    if patches is not None:  # llava: precomputed patch embeddings (stub frontend)
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    return x * jnp.array(math.sqrt(cfg.d_model) if cfg.family == "vlm_scaled"
                         else 1.0, x.dtype)


def unembed(params: dict, cfg: ModelConfig, h: Array) -> Array:
    base = params["base"]
    if cfg.tie_embeddings:
        logits = h @ base["embed"].T.astype(h.dtype)
    else:
        logits = h @ base["lm_head"]
    from repro.dist.sharding import act_hint
    logits = act_hint(logits, "batch", None, "model")
    v = cfg.vocab * max(cfg.n_codebooks, 1)
    if logits.shape[-1] != v:  # drop vocab-padding columns
        logits = logits[..., :v]
    logits = L.softcap(logits, cfg.final_softcap)
    if cfg.n_codebooks:
        logits = logits.reshape(*logits.shape[:-1], cfg.n_codebooks, cfg.vocab)
    return logits


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------


def _stacked_to_steps(tree, n_sub: int):
    """[L, ...] -> [L/n_sub, n_sub, ...] for scan over sublayer groups."""
    return jax.tree.map(lambda x: x.reshape(x.shape[0] // n_sub, n_sub,
                                            *x.shape[1:]), tree)


def lm_forward(params: dict, cfg: ModelConfig, tokens: Array,
               patches: Array | None = None, positions: Array | None = None,
               caches: list | None = None, skip_unembed: bool = False,
               fusion_mask: Array | None = None) -> tuple[Array, list | None, Array]:
    """-> (logits | final hidden, updated caches | None, moe aux loss).

    ``fusion_mask`` [B, n_heads*head_dim] zeroes absent-modality blocks of
    the fusion (``wo``) projection input — the serving engine's chunked
    prefill passes the request's modality mask here so prefill and decode
    see identical masked features.
    """
    x = embed_tokens(params, cfg, tokens, patches)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    ctx = None if fusion_mask is None else {"fusion_mask": fusion_mask}
    n_sub, windows = pattern(cfg)
    n_steps = cfg.n_layers // n_sub

    layer_p = _stacked_to_steps(params["base"]["layers"], n_sub)
    lora_layers = params.get("lora", {}).get("layers")
    lora_p = _stacked_to_steps(lora_layers, n_sub) if lora_layers is not None else None

    def body(carry, step):
        x, aux = carry
        p_step, lp_step, cache_step = step
        new_caches = []
        for s in range(n_sub):
            p_s = jax.tree.map(lambda a: a[s], p_step)
            lp_s = jax.tree.map(lambda a: a[s], lp_step) if lp_step is not None else None
            c_s = None if cache_step is None else jax.tree.map(lambda a: a[s], cache_step)
            x, nc, a = _sublayer(p_s, lp_s, cfg, x, positions, c_s, windows[s],
                                 ctx)
            new_caches.append(nc)
            aux = aux + a
        stacked_nc = (None if cache_step is None else
                      jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches))
        return (x, aux), stacked_nc

    body = _remat_wrap(body, cfg)
    caches_steps = None if caches is None else _stacked_to_steps(caches, n_sub)

    if cfg.scan_layers:
        (x, aux), nc = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                    (layer_p, lora_p, caches_steps))
    else:  # unrolled (dry-run: exact per-layer cost/collective accounting)
        carry = (x, jnp.float32(0.0))
        ncs = []
        for t in range(n_steps):
            step = (jax.tree.map(lambda a: a[t], layer_p),
                    None if lora_p is None else
                    jax.tree.map(lambda a: a[t], lora_p),
                    None if caches_steps is None else
                    jax.tree.map(lambda a: a[t], caches_steps))
            carry, nc_t = body(carry, step)
            ncs.append(nc_t)
        (x, aux) = carry
        nc = (None if caches_steps is None else
              jax.tree.map(lambda *xs: jnp.stack(xs), *ncs))
    new_caches = (None if caches is None else jax.tree.map(
        lambda a: a.reshape(n_steps * n_sub, *a.shape[2:]), nc))

    x = L.rmsnorm(params["base"]["final_norm"], x)
    if skip_unembed:
        return x, new_caches, aux
    return unembed(params, cfg, x), new_caches, aux


# ---------------------------------------------------------------------------
# KV caches / decode
# ---------------------------------------------------------------------------


def cache_len(cfg: ModelConfig, sub: int, max_len: int) -> int:
    _, windows = pattern(cfg)
    return int(min(windows[sub], max_len))


def init_kv_caches(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=None, per_row_pos: bool = False) -> dict:
    """Per-layer ring-buffer caches, stacked [L, B, T_l, K, hd].

    With an alternating pattern the two sublayer groups have different ring
    sizes, so caches are stored per *scan step* with a [n_steps]-leading tree
    of per-sublayer entries; uniform patterns collapse to a single [L,...] set.
    Ring size = min(window, max_len) — sliding-window layers never allocate
    more than their window (this is what makes long_500k feasible).

    ``per_row_pos`` allocates the position leaf per batch row ([L, B, T_l]
    instead of [L, T_l]) so each row can sit at its own sequence depth —
    the continuous-batching serving engine's layout.
    """
    dtype = dtype or cfg.runtime_dtype()
    n_sub, windows = pattern(cfg)
    K, hd = cfg.n_kv_heads, cfg.head_dim
    n_steps = cfg.n_layers // n_sub
    # store as [L, ...] where sublayer s of step t is layer t*n_sub+s; ring
    # sizes differ per sublayer => pad rings to per-sublayer size via a list
    # of stacked arrays, one per sublayer slot, interleaved back in forward.
    caches = []
    for s in range(n_sub):
        T = int(min(windows[s], max_len))
        pos_shape = (n_steps, batch, T) if per_row_pos else (n_steps, T)
        if cfg.kv_quant:
            caches.append({
                "k": jnp.zeros((n_steps, batch, T, K, hd), jnp.int8),
                "v": jnp.zeros((n_steps, batch, T, K, hd), jnp.int8),
                "k_scale": jnp.zeros((n_steps, batch, T, K), jnp.float32),
                "v_scale": jnp.zeros((n_steps, batch, T, K), jnp.float32),
                "pos": jnp.full(pos_shape, -1, dtype=jnp.int32),
            })
        else:
            caches.append({
                "k": jnp.zeros((n_steps, batch, T, K, hd), dtype=dtype),
                "v": jnp.zeros((n_steps, batch, T, K, hd), dtype=dtype),
                "pos": jnp.full(pos_shape, -1, dtype=jnp.int32),
            })
    # interleave sublayer slots back into a [L, ...]-indexed tree when ring
    # sizes agree; otherwise keep the per-sublayer list (forward handles both)
    if n_sub == 1:
        return caches[0]
    if len({c["k"].shape[2] for c in caches}) == 1:
        return jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=1).reshape(
                n_steps * n_sub, *xs[0].shape[1:]), *caches)
    return {"__per_sub__": caches}


def _caches_for_scan(cfg: ModelConfig, caches):
    """Normalize cache container to per-step [n_steps, n_sub(list), ...]."""
    n_sub, _ = pattern(cfg)
    if isinstance(caches, dict) and "__per_sub__" in caches:
        return caches["__per_sub__"]
    return caches


def lm_decode_step(params: dict, cfg: ModelConfig, caches, token: Array,
                   pos: Array, adapter_idx: Array | None = None,
                   fusion_mask: Array | None = None,
                   lora_impl: str = "xla") -> tuple[Array, Any]:
    """One-token decode. token: [B, 1]; pos: scalar int32 (all rows at the
    same depth) or [B] int32 (per-row depths — continuous batching; requires
    caches built with ``per_row_pos=True``).

    ``adapter_idx`` [B] selects each row's adapter from [A, ...]-stacked
    LoRA leaves (gathered multi-tenant decode); ``fusion_mask``
    [B, n_heads*head_dim] zeroes absent-modality fusion blocks per row.
    """
    x = embed_tokens(params, cfg, token)
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[None] if pos.ndim == 0 else pos[:, None]
    ctx = None
    if adapter_idx is not None or fusion_mask is not None:
        ctx = {"adapter_idx": adapter_idx, "fusion_mask": fusion_mask,
               "lora_impl": lora_impl}
    n_sub, windows = pattern(cfg)
    n_steps = cfg.n_layers // n_sub

    layer_p = _stacked_to_steps(params["base"]["layers"], n_sub)
    lora_layers = params.get("lora", {}).get("layers")
    lora_p = _stacked_to_steps(lora_layers, n_sub) if lora_layers is not None else None

    per_sub = isinstance(caches, dict) and "__per_sub__" in caches
    cache_in = (caches["__per_sub__"] if per_sub
                else _stacked_to_steps(caches, n_sub))

    def body(x, step):
        p_step, lp_step, cache_step = step
        new_caches = []
        for s in range(n_sub):
            p_s = jax.tree.map(lambda a: a[s], p_step)
            lp_s = jax.tree.map(lambda a: a[s], lp_step) if lp_step is not None else None
            c_s = cache_step[s] if per_sub else jax.tree.map(lambda a: a[s], cache_step)
            x, nc, _ = _sublayer(p_s, lp_s, cfg, x, positions, c_s, windows[s],
                                 ctx)
            new_caches.append(nc)
        out = (tuple(new_caches) if per_sub
               else jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches))
        return x, out

    if not cfg.scan_layers:  # unrolled decode (dry-run accounting)
        ncs_list = []
        for t in range(n_steps):
            step = (jax.tree.map(lambda a: a[t], layer_p),
                    None if lora_p is None else
                    jax.tree.map(lambda a: a[t], lora_p),
                    tuple(jax.tree.map(lambda a: a[t], c) for c in cache_in)
                    if per_sub else
                    jax.tree.map(lambda a: a[t], cache_in))
            x, nc_t = body(x, step)
            ncs_list.append(nc_t)
        if per_sub:
            new_caches = {"__per_sub__": [
                jax.tree.map(lambda *xs: jnp.stack(xs),
                             *[nc[s] for nc in ncs_list])
                for s in range(n_sub)]}
        else:
            nc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs_list)
            new_caches = jax.tree.map(
                lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), nc)
    elif per_sub:
        x, ncs = jax.lax.scan(body, x, (layer_p, lora_p, tuple(cache_in)))
        new_caches = {"__per_sub__": list(ncs)}
    else:
        x, nc = jax.lax.scan(body, x, (layer_p, lora_p, cache_in))
        new_caches = jax.tree.map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), nc)

    x = L.rmsnorm(params["base"]["final_norm"], x)
    return unembed(params, cfg, x), new_caches
