"""Family-dispatching model API — the single entry point used by training,
serving, the federated engine and the dry-run launcher.

  init_model(key, cfg)                  -> params {"base":..., "lora":...}
  forward(params, cfg, batch)           -> (logits, aux_loss)
  loss_fn(params, cfg, batch)           -> scalar loss
  init_caches(cfg, batch_size, max_len) -> decode caches
  decode_step(params, cfg, caches, token, pos) -> (logits, caches)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import hybrid as HY
from repro.models import layers as L
from repro.models import ssm as SM
from repro.models import transformer as TF

Array = jax.Array

_TF_FAMILIES = ("dense", "moe", "vlm", "audio")


def init_model(key: Array, cfg: ModelConfig, with_lora: bool = True) -> dict:
    if cfg.family in _TF_FAMILIES:
        return TF.init_lm(key, cfg, with_lora)
    if cfg.family == "ssm":
        return SM.init_mamba_lm(key, cfg)
    if cfg.family == "hybrid":
        return HY.init_hybrid_lm(key, cfg, with_lora)
    raise ValueError(f"unknown family {cfg.family}")


def forward(params: dict, cfg: ModelConfig, batch: dict) -> tuple[Array, Array]:
    if cfg.family in _TF_FAMILIES:
        logits, _, aux = TF.lm_forward(params, cfg, batch["tokens"],
                                       patches=batch.get("patches"))
    elif cfg.family == "ssm":
        logits, _, aux = SM.mamba_forward(params, cfg, batch["tokens"])
    elif cfg.family == "hybrid":
        logits, _, aux = HY.hybrid_forward(params, cfg, batch["tokens"])
    else:
        raise ValueError(cfg.family)
    return logits, aux


def forward_hidden(params: dict, cfg: ModelConfig, batch: dict):
    """Forward up to the final norm (pre-unembed). Used by chunked-CE
    training and by prefill (which unembeds only the last position)."""
    if cfg.family in _TF_FAMILIES:
        return TF.lm_forward(params, cfg, batch["tokens"],
                             patches=batch.get("patches"), skip_unembed=True)
    if cfg.family == "ssm":
        return SM.mamba_forward(params, cfg, batch["tokens"],
                                skip_unembed=True)
    if cfg.family == "hybrid":
        return HY.hybrid_forward(params, cfg, batch["tokens"],
                                 skip_unembed=True)
    raise ValueError(cfg.family)


def chunked_ce(params: dict, cfg: ModelConfig, h: Array, labels: Array,
               n_chunks: int) -> Array:
    """CE over vocab computed per sequence-chunk: the [B, S, V] logits
    transient shrinks to [B, S/n_chunks, V] (production large-vocab path)."""
    from repro.models import transformer as TF

    B, S, _ = h.shape
    assert S % n_chunks == 0, (S, n_chunks)
    hc = h.reshape(B, n_chunks, S // n_chunks, -1).transpose(1, 0, 2, 3)
    tail = labels.shape[2:]  # audio: [B, S, n_codebooks]
    lc = labels.reshape(B, n_chunks, S // n_chunks, *tail)
    lc = jnp.moveaxis(lc, 1, 0)

    def one(c, args):
        hi, li = args
        logits = TF.unembed(params, cfg, hi)
        return c + L.cross_entropy_logits(logits, li), None

    total, _ = jax.lax.scan(one, jnp.float32(0.0), (hc, lc))
    return total / n_chunks


def loss_fn(params: dict, cfg: ModelConfig, batch: dict,
            aux_weight: float = 0.01) -> Array:
    labels = batch["labels"]
    if cfg.loss_chunks > 1 and cfg.family in _TF_FAMILIES:
        from repro.models import transformer as TF
        h, _, aux = TF.lm_forward(params, cfg, batch["tokens"],
                                  patches=batch.get("patches"),
                                  skip_unembed=True)
        if "patches" in batch and batch["patches"] is not None:
            h = h[:, batch["patches"].shape[1]:]
        return chunked_ce(params, cfg, h, labels, cfg.loss_chunks) \
            + aux_weight * aux
    logits, aux = forward(params, cfg, batch)
    if "patches" in batch and batch["patches"] is not None:
        # llava: loss only over the text positions (after the patch prefix)
        n_patch = batch["patches"].shape[1]
        logits = logits[:, n_patch:]
    return L.cross_entropy_logits(logits, labels) + aux_weight * aux


def init_caches(cfg: ModelConfig, batch: int, max_len: int,
                per_row_pos: bool = False) -> Any:
    """``per_row_pos`` gives every batch row its own cache position leaf so
    rows can sit at different sequence depths (continuous batching)."""
    if cfg.family in _TF_FAMILIES:
        return TF.init_kv_caches(cfg, batch, max_len, per_row_pos=per_row_pos)
    if cfg.family == "ssm":
        return SM.init_mamba_caches(cfg, batch, max_len)  # positionless state
    if cfg.family == "hybrid":
        return HY.init_hybrid_caches(cfg, batch, max_len,
                                     per_row_pos=per_row_pos)
    raise ValueError(cfg.family)


def decode_step(params: dict, cfg: ModelConfig, caches: Any, token: Array,
                pos: Array, adapter_idx: Array | None = None,
                fusion_mask: Array | None = None,
                lora_impl: str = "xla") -> tuple[Array, Any]:
    """One decode step. ``pos`` is a scalar (all rows at the same depth) or
    [B] (per-row depths; needs ``init_caches(per_row_pos=True)``).
    ``adapter_idx`` [B] selects per-row adapters from [A, ...]-stacked LoRA
    leaves (gathered multi-tenant decode); ``fusion_mask`` [B, fusion_dim]
    zeroes absent-modality blocks of the fusion projection input."""
    if cfg.family in _TF_FAMILIES:
        return TF.lm_decode_step(params, cfg, caches, token, pos,
                                 adapter_idx=adapter_idx,
                                 fusion_mask=fusion_mask, lora_impl=lora_impl)
    if cfg.family == "ssm":
        if adapter_idx is not None or fusion_mask is not None:
            raise ValueError("ssm family has no fusion projection; "
                             "multi-adapter decode is not supported")
        return SM.mamba_decode_step(params, cfg, caches, token, pos)
    if cfg.family == "hybrid":
        return HY.hybrid_decode_step(params, cfg, caches, token, pos,
                                     adapter_idx=adapter_idx,
                                     fusion_mask=fusion_mask,
                                     lora_impl=lora_impl)
    raise ValueError(cfg.family)


def fusion_block_dims(cfg: ModelConfig) -> tuple[int, ...]:
    """Modality-aligned column blocks of the fusion (``wo``) input axis.

    hybrid: (attention features, SSD features) — the RELIEF Eq. 1 layout.
    Attention families: one block per KV group (the concatenated-head axis
    is K-major after the [B, S, K, G, hd] reshape), giving head-group
    granularity for modality masks.
    """
    if cfg.family == "hybrid":
        dm = HY.hybrid_dims(cfg)
        return (dm["attn_out"], dm["d_inner"])
    if cfg.family in _TF_FAMILIES:
        g = cfg.n_heads // cfg.n_kv_heads
        return (g * cfg.head_dim,) * cfg.n_kv_heads
    raise ValueError(f"{cfg.family} has no fusion projection")


def prefill_with_cache(params: dict, cfg: ModelConfig, caches: Any,
                       tokens: Array, patches: Array | None = None,
                       fusion_mask: Array | None = None
                       ) -> tuple[Array, Any]:
    """Prefill ``tokens`` [B, S] into ``caches``; -> (last-position logits
    [B, 1, V], updated caches).

    Attention families run one chunked forward over the whole prompt (the
    q_chunk-tiled attention bounds peak memory) when every cache ring can
    hold it; prompts longer than a sliding-window ring would overwrite
    slots mid-forward, so those fall back to the exact per-token loop.
    Recurrent families (ssm, hybrid) must advance their state
    token-by-token — the cache path *is* the recurrence there.
    Assumes fresh caches (prefill starts at position 0).
    """
    B, S = tokens.shape[:2]
    if cfg.family in _TF_FAMILIES:
        if isinstance(caches, dict) and "__per_sub__" in caches:
            min_ring = min(c["k"].shape[2] for c in caches["__per_sub__"])
        else:
            min_ring = caches["k"].shape[2]
        if S <= min_ring:
            positions = jnp.arange(S, dtype=jnp.int32)
            h, caches, _ = TF.lm_forward(params, cfg, tokens, patches=patches,
                                         positions=positions, caches=caches,
                                         skip_unembed=True,
                                         fusion_mask=fusion_mask)
            return TF.unembed(params, cfg, h[:, -1:]), caches
        logits = None
        for t in range(S):
            logits, caches = decode_step(params, cfg, caches,
                                         tokens[:, t:t + 1], jnp.int32(t),
                                         fusion_mask=fusion_mask)
        return logits, caches
    if cfg.family not in ("ssm", "hybrid"):
        raise ValueError(cfg.family)
    logits = None
    for t in range(S):
        logits, caches = decode_step(
            params, cfg, caches, tokens[:, t:t + 1], jnp.int32(t),
            fusion_mask=fusion_mask if cfg.family == "hybrid" else None)
    return logits, caches


def param_count(params: Any) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
