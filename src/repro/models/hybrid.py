"""Hymba-style hybrid blocks: parallel attention heads + Mamba(SSD) heads.

Each layer runs an attention mixer and an SSD mixer *in parallel on the same
normalized input*; their outputs are concatenated and fused by a single
output projection (arXiv:2411.13676).  That fusion projection's input axis is
an ordered concatenation of the two head families — exactly the paper's
"modality-aligned column block" structure (Eq. 1) — so RELIEF's MDLoRA blocks
attach natively here: block 0 = attention features, block 1 = SSM features.
Meta tokens from the Hymba paper are out of scope (frontend-level, stubbed).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.transformer import unembed

Array = jax.Array


def hybrid_dims(cfg: ModelConfig) -> dict:
    dm = S.mixer_dims(cfg)
    attn_out = cfg.n_heads * cfg.head_dim
    return dm | {"attn_out": attn_out, "fused": attn_out + dm["d_inner"]}


def init_hybrid_layer(key: Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ka, km, ko, kf = jax.random.split(key, 4)
    dm = hybrid_dims(cfg)
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kq, kk, kv = jax.random.split(ka, 3)
    return {
        "attn": {
            "wq": L.dense_init(kq, d, h * hd, dtype),
            "wk": L.dense_init(kk, d, k * hd, dtype),
            "wv": L.dense_init(kv, d, k * hd, dtype),
        },
        "mamba": init_mamba_headless(km, cfg, dtype),
        # fusion projection: input = [attn_out ; ssm_out] (RELIEF block axis)
        "wo": L.dense_init(ko, dm["fused"], d, dtype),
        "mlp": L.init_glu_mlp(kf, d, cfg.d_ff, dtype),
        "ln1": L.init_rmsnorm(d, dtype),
        "ln2": L.init_rmsnorm(d, dtype),
    }


def init_mamba_headless(key: Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    """Mamba mixer without its own out_proj (fusion happens in wo)."""
    p = S.init_mamba_mixer(key, cfg, dtype)
    del p["out_proj"]
    return p


def _attn_heads(p: dict, lp: dict | None, cfg: ModelConfig, x: Array,
                positions: Array, cache: dict | None, window,
                ctx: dict | None = None):
    from repro.models.transformer import _cache_scatter, _pos_scatter, _proj

    B, Sq, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = _proj(p["wq"], lp, "wq", x, cfg, ctx).reshape(B, Sq, H, hd)
    k = _proj(p["wk"], lp, "wk", x, cfg, ctx).reshape(B, Sq, K, hd)
    v = _proj(p["wv"], lp, "wv", x, cfg, ctx).reshape(B, Sq, K, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        kk, vv, kv_pos = k, v, positions
    else:
        T = cache["k"].shape[1]
        slots = positions % T
        kk = _cache_scatter(cache["k"], slots, k.astype(cache["k"].dtype))
        vv = _cache_scatter(cache["v"], slots, v.astype(cache["v"].dtype))
        kv_pos = _pos_scatter(cache["pos"], slots, positions)
        new_cache = {"k": kk, "v": vv, "pos": kv_pos}

    qg = q.reshape(B, Sq, K, H // K, hd)
    o = L._chunked_attention(qg, kk, vv, positions, kv_pos, window,
                             cfg.attn_softcap, cfg.q_chunk)
    return o.reshape(B, Sq, H * hd), new_cache


def hybrid_layer(p: dict, lp: dict | None, cfg: ModelConfig, x: Array,
                 positions: Array, caches: dict | None, window,
                 ctx: dict | None = None):
    """caches = {"attn": kv-cache, "ssm": {"conv","state"}} or None."""
    from repro.models.transformer import _proj

    h = L.rmsnorm(p["ln1"], x)
    attn_cache = None if caches is None else caches["attn"]
    ssm_cache = None if caches is None else caches["ssm"]

    attn_out, new_attn = _attn_heads(p["attn"], lp, cfg, h, positions,
                                     attn_cache, window, ctx)
    ssm_out, new_ssm = S.mamba_mixer(p["mamba"], cfg, h, ssm_cache=ssm_cache,
                                     return_fused_input=True)
    fused = jnp.concatenate([attn_out, ssm_out], axis=-1)
    y = _proj(p["wo"], lp, "wo", fused, cfg, ctx)
    x = x + y
    h2 = L.rmsnorm(p["ln2"], x)
    x = x + L.glu_mlp(p["mlp"], h2, cfg.activation)
    new_caches = None if caches is None else {"attn": new_attn, "ssm": new_ssm}
    return x, new_caches


# ---------------------------------------------------------------------------
# LM wrapper
# ---------------------------------------------------------------------------


def init_hybrid_lora(key: Array, cfg: ModelConfig) -> dict:
    dt = jnp.float32 if cfg.lora_dtype == "float32" else cfg.p_dtype()
    r = cfg.lora_rank
    dm = hybrid_dims(cfg)
    d = cfg.d_model
    shapes = {"wq": (d, cfg.n_heads * cfg.head_dim),
              "wv": (d, cfg.n_kv_heads * cfg.head_dim),
              "wo": (dm["fused"], d)}

    def one_layer(k):
        out = {}
        for name, (din, dout) in shapes.items():
            if name not in cfg.lora_targets and not (
                    name == "wo" and "wo_fusion" in cfg.lora_targets):
                continue
            k, ka = jax.random.split(k)
            out[name] = {"a": (jax.random.normal(ka, (din, r)) /
                               math.sqrt(din)).astype(dt),
                         "b": jnp.zeros((r, dout), dtype=dt)}
        return out

    return jax.vmap(one_layer)(jax.random.split(key, cfg.n_layers))


def init_hybrid_lm(key: Array, cfg: ModelConfig, with_lora: bool = True) -> dict:
    from repro.models.transformer import padded_vocab

    ke, kl, klo = jax.random.split(key, 3)
    dt = cfg.p_dtype()
    params = {"base": {
        "embed": L.embed_init(ke, padded_vocab(cfg), cfg.d_model, dt),
        "layers": jax.vmap(lambda k: init_hybrid_layer(k, cfg, dt))(
            jax.random.split(kl, cfg.n_layers)),
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
    }}
    if with_lora:
        params["lora"] = {"layers": init_hybrid_lora(klo, cfg)}
    return params


def _window(cfg: ModelConfig):
    import numpy as np
    return cfg.sliding_window if cfg.sliding_window is not None else \
        np.iinfo(np.int32).max


def hybrid_forward(params: dict, cfg: ModelConfig, tokens: Array,
                   caches=None, skip_unembed: bool = False
                   ) -> tuple[Array, Any, Array]:
    x = jnp.take(params["base"]["embed"], tokens, axis=0).astype(cfg.runtime_dtype())
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    lora_layers = params.get("lora", {}).get("layers")

    def body(x, step):
        p, lp = step
        x, _ = hybrid_layer(p, lp, cfg, x, positions, None, _window(cfg))
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, (params["base"]["layers"], lora_layers))
    else:  # unrolled (dry-run accounting)
        for t in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(
                lambda a: a[t], (params["base"]["layers"], lora_layers)))
    x = L.rmsnorm(params["base"]["final_norm"], x)
    if skip_unembed:
        return x, None, jnp.float32(0.0)
    return unembed(params, cfg, x), None, jnp.float32(0.0)


def init_hybrid_caches(cfg: ModelConfig, batch: int, max_len: int,
                       dtype=None, per_row_pos: bool = False) -> dict:
    dtype = dtype or cfg.runtime_dtype()
    dm = hybrid_dims(cfg)
    T = int(min(_window(cfg), max_len))
    Lyr = cfg.n_layers
    pos_shape = (Lyr, batch, T) if per_row_pos else (Lyr, T)
    return {
        "attn": {"k": jnp.zeros((Lyr, batch, T, cfg.n_kv_heads, cfg.head_dim), dtype),
                 "v": jnp.zeros((Lyr, batch, T, cfg.n_kv_heads, cfg.head_dim), dtype),
                 "pos": jnp.full(pos_shape, -1, jnp.int32)},
        "ssm": {"conv": jnp.zeros((Lyr, batch, cfg.conv_kernel - 1, dm["conv_dim"]), dtype),
                "state": jnp.zeros((Lyr, batch, dm["n_heads"], dm["p"], dm["n"]),
                                   jnp.float32)},
    }


def hybrid_decode_step(params: dict, cfg: ModelConfig, caches: dict,
                       token: Array, pos: Array,
                       adapter_idx: Array | None = None,
                       fusion_mask: Array | None = None,
                       lora_impl: str = "xla"):
    x = jnp.take(params["base"]["embed"], token, axis=0).astype(cfg.runtime_dtype())
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[None] if pos.ndim == 0 else pos[:, None]
    ctx = None
    if adapter_idx is not None or fusion_mask is not None:
        ctx = {"adapter_idx": adapter_idx, "fusion_mask": fusion_mask,
               "lora_impl": lora_impl}
    lora_layers = params.get("lora", {}).get("layers")

    def body(x, step):
        p, lp, cache = step
        x, nc = hybrid_layer(p, lp, cfg, x, positions, cache, _window(cfg),
                             ctx)
        return x, nc

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(
            body, x, (params["base"]["layers"], lora_layers, caches))
    else:
        ncs = []
        for t in range(cfg.n_layers):
            x, nc = body(x, jax.tree.map(
                lambda a: a[t],
                (params["base"]["layers"], lora_layers, caches)))
            ncs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
    x = L.rmsnorm(params["base"]["final_norm"], x)
    return unembed(params, cfg, x), new_caches
