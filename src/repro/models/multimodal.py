"""The paper's multimodal sensing model (Section III-B).

Per-modality encoders E_m -> features h_m in R^{d_m}; the fusion layer takes
the ordered concatenation h = [h_1; ...; h_M] in R^D and its (LoRA) projection
matrix carries the modality-aligned column-block structure of Eq. (1).  A task
head classifies the fused representation.

Two backbones, as in the paper (Section VI-A3):
  * ``cnn``         — Backbone 1: 2-layer 1-D CNN encoders, full-parameter
                      training; fusion weight itself is column-blocked.
  * ``transformer`` — Backbone 2: frozen patch-transformer encoders (MOMENT
                      stand-in; see DESIGN.md §9) + LoRA adapters (rho=8) on
                      attention Q/V and the FFN, MDLoRA on the fusion layer.

Missing modalities: inputs are zero-padded (paper Eq. 2) and the encoder
output h_m is zeroed, so block A_m receives exactly zero gradient for absent
modalities (the paper's Assumption 4 with eps_0 = 0).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModalitySpec:
    name: str
    channels: int
    d_feat: int  # d_m


@dataclasses.dataclass(frozen=True)
class MMConfig:
    name: str
    modalities: tuple[ModalitySpec, ...]
    window: int = 256  # 5.12 s @ 50 Hz (paper VI-A1)
    n_classes: int = 12
    backbone: str = "cnn"  # cnn | transformer
    d_fused: int = 128
    head_hidden: int = 64
    # cnn encoder
    cnn_ch: tuple[int, int] = (32, 64)
    cnn_kernel: int = 5
    # transformer encoder (frozen)
    enc_layers: int = 2
    enc_d: int = 64
    enc_heads: int = 4
    enc_ff: int = 128
    patch: int = 16
    # LoRA
    lora_rank: int = 8
    lora_alpha: float = 16.0
    dtype: str = "float32"

    @property
    def M(self) -> int:
        return len(self.modalities)

    @property
    def D(self) -> int:
        return sum(m.d_feat for m in self.modalities)

    @property
    def block_dims(self) -> tuple[int, ...]:
        return tuple(m.d_feat for m in self.modalities)

    @property
    def total_channels(self) -> int:
        return sum(m.channels for m in self.modalities)

    def runtime_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# encoders
# ---------------------------------------------------------------------------


def _init_cnn_encoder(key: Array, spec: ModalitySpec, cfg: MMConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    c1, c2 = cfg.cnn_ch
    return {
        "conv1": L.init_conv1d(k1, spec.channels, c1, cfg.cnn_kernel),
        "conv2": L.init_conv1d(k2, c1, c2, cfg.cnn_kernel),
        "proj": L.dense_init(k3, c2, spec.d_feat),
    }


def _cnn_encoder(p: dict, x: Array) -> Array:
    """x: [B, T, C] -> [B, d_feat]."""
    h = jax.nn.relu(L.conv1d(p["conv1"], x, stride=2))
    h = jax.nn.relu(L.conv1d(p["conv2"], h, stride=2))
    h = jnp.mean(h, axis=1)  # global average pool
    return h @ p["proj"]


def _init_tx_encoder(key: Array, spec: ModalitySpec, cfg: MMConfig) -> dict:
    kp, kl, ko = jax.random.split(key, 3)
    d = cfg.enc_d

    def one_layer(k):
        ka, km = jax.random.split(k)
        dims = L.AttnDims(d, cfg.enc_heads, cfg.enc_heads, d // cfg.enc_heads)
        return {"attn": L.init_attention(ka, dims), "mlp": L.init_glu_mlp(km, d, cfg.enc_ff),
                "ln1": L.init_rmsnorm(d), "ln2": L.init_rmsnorm(d)}

    return {
        "patch": L.dense_init(kp, cfg.patch * spec.channels, d),
        "layers": jax.vmap(one_layer)(jax.random.split(kl, cfg.enc_layers)),
        "proj": L.dense_init(ko, d, spec.d_feat),
    }


def _init_tx_lora(key: Array, spec: ModalitySpec, cfg: MMConfig) -> dict:
    """LoRA on Q/V + FFN of each encoder layer (paper VI-A3)."""
    d, r = cfg.enc_d, cfg.lora_rank

    def one_layer(k):
        out = {}
        for name, (din, dout) in (("wq", (d, d)), ("wv", (d, d)),
                                  ("wi", (d, cfg.enc_ff))):
            k, ka = jax.random.split(k)
            out[name] = {"a": (jax.random.normal(ka, (din, r)) / math.sqrt(din)),
                         "b": jnp.zeros((r, dout))}
        return out

    return jax.vmap(one_layer)(jax.random.split(key, cfg.enc_layers))


def _tx_encoder(p: dict, lp: dict | None, cfg: MMConfig, x: Array) -> Array:
    """x: [B, T, C] -> [B, d_feat]; bidirectional patch transformer."""
    B, T, C = x.shape
    P = cfg.patch
    n_tok = T // P
    tok = x[:, : n_tok * P].reshape(B, n_tok, P * C) @ p["patch"]
    scale = cfg.lora_alpha / cfg.lora_rank

    def lora(lp_l, name, h):
        if lp_l is None:
            return 0.0
        return ((h @ lp_l[name]["a"]) @ lp_l[name]["b"]) * scale

    def body(h, step):
        pl, lpl = step
        hn = L.rmsnorm(pl["ln1"], h)
        dims = L.AttnDims(cfg.enc_d, cfg.enc_heads, cfg.enc_heads,
                          cfg.enc_d // cfg.enc_heads)
        H, hd = dims.n_heads, dims.head_dim
        q = (hn @ pl["attn"]["wq"] + lora(lpl, "wq", hn)).reshape(B, n_tok, H, hd)
        k = (hn @ pl["attn"]["wk"]).reshape(B, n_tok, H, hd)
        v = (hn @ pl["attn"]["wv"] + lora(lpl, "wv", hn)).reshape(B, n_tok, H, hd)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
        o = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
        h = h + o.reshape(B, n_tok, H * hd) @ pl["attn"]["wo"]
        hn = L.rmsnorm(pl["ln2"], h)
        up = hn @ pl["mlp"]["wi"] + lora(lpl, "wi", hn)
        h = h + (jax.nn.silu(hn @ pl["mlp"]["wg"]) * up) @ pl["mlp"]["wo"]
        return h, None

    h, _ = jax.lax.scan(body, tok,
                        (p["layers"], None if lp is None else lp["layers"]))
    return jnp.mean(h, axis=1) @ p["proj"]


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def init_mm_model(key: Array, cfg: MMConfig) -> dict:
    keys = jax.random.split(key, cfg.M + 4)
    init_enc = _init_cnn_encoder if cfg.backbone == "cnn" else _init_tx_encoder
    encoders = {m.name: init_enc(keys[i], m, cfg)
                for i, m in enumerate(cfg.modalities)}
    kf, kh1, kh2 = keys[cfg.M: cfg.M + 3]
    base = {
        "encoders": encoders,
        "fusion_w0": L.dense_init(kf, cfg.D, cfg.d_fused),
        "head": {"w1": L.dense_init(kh1, cfg.d_fused, cfg.head_hidden),
                 "w2": L.dense_init(kh2, cfg.head_hidden, cfg.n_classes)},
    }
    params: dict[str, Any] = {"base": base}
    klo = keys[-1]
    kfa, kfb, *kencs = jax.random.split(klo, 2 + cfg.M)
    r = cfg.lora_rank
    lora: dict[str, Any] = {
        # fusion LoRA: a is [D, r] = A^T; modality blocks are row ranges of a
        "fusion": {"a": (jax.random.normal(kfa, (cfg.D, r)) / math.sqrt(cfg.D)),
                   "b": jnp.zeros((r, cfg.d_fused))},
    }
    if cfg.backbone == "transformer":
        lora["encoders"] = {m.name: {"layers": _init_tx_lora(kencs[i], m, cfg)}
                            for i, m in enumerate(cfg.modalities)}
    params["lora"] = lora
    return params


def split_modalities(cfg: MMConfig, x: Array) -> dict[str, Array]:
    """x: [B, T, total_channels] (ordered concat) -> per-modality slices."""
    out, off = {}, 0
    for m in cfg.modalities:
        out[m.name] = x[..., off: off + m.channels]
        off += m.channels
    return out


def mm_features(params: dict, cfg: MMConfig, x: Array,
                modality_mask: Array) -> Array:
    """-> fused-input features h = [h_1; ...; h_M] with absent blocks zeroed.

    modality_mask: [M] or [B, M] float/bool; h_m := E_m(x_m) * mask_m, so the
    fusion block A_m of an absent modality receives exactly zero gradient.
    """
    xs = split_modalities(cfg, x)
    lora_enc = params.get("lora", {}).get("encoders")
    hs = []
    for i, m in enumerate(cfg.modalities):
        if cfg.backbone == "cnn":
            h = _cnn_encoder(params["base"]["encoders"][m.name], xs[m.name])
        else:
            lp = None if lora_enc is None else lora_enc[m.name]
            h = _tx_encoder(params["base"]["encoders"][m.name], lp, cfg,
                            xs[m.name])
        mask = modality_mask[..., i: i + 1].astype(h.dtype)
        hs.append(h * mask)
    return jnp.concatenate(hs, axis=-1)  # [B, D]


def mm_forward(params: dict, cfg: MMConfig, x: Array,
               modality_mask: Array) -> Array:
    """-> logits [B, n_classes]."""
    h = mm_features(params, cfg, x, modality_mask)
    scale = cfg.lora_alpha / cfg.lora_rank
    fused = h @ params["base"]["fusion_w0"]
    lora = params.get("lora")
    if lora is not None and "fusion" in lora:
        fused = fused + ((h @ lora["fusion"]["a"]) @ lora["fusion"]["b"]) * scale
    z = jax.nn.relu(fused)
    z = jax.nn.relu(z @ params["base"]["head"]["w1"])
    return z @ params["base"]["head"]["w2"]


def mm_loss(params: dict, cfg: MMConfig, batch: dict) -> Array:
    logits = mm_forward(params, cfg, batch["x"], batch["modality_mask"])
    return L.cross_entropy_logits(logits, batch["y"])
