"""Mixtral-style sparse MoE MLP (top-k routing over SwiGLU experts).

Dispatch is sort-based with a fixed per-shard capacity: the (token, expert)
assignments are sorted by expert id and gathered into an [E*C, d] buffer, so
expert compute is a single grouped matmul whose FLOPs equal the *active*
expert FLOPs (× capacity_factor) — no [tokens, E, C] dispatch einsum (which
would dominate the roofline) and no dense all-experts compute (which would
inflate HLO FLOPs by E/top_k). Overflowing tokens are dropped (standard
capacity semantics); combine is a scatter-add weighted by router probs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array


def init_moe_mlp(key: Array, d_model: int, d_ff: int, n_experts: int,
                 dtype=jnp.float32) -> dict:
    kr, ki, kg, ko = jax.random.split(key, 4)
    ei = jax.vmap(lambda k: L.dense_init(k, d_model, d_ff, dtype))
    eo = jax.vmap(lambda k: L.dense_init(k, d_ff, d_model, dtype))
    return {
        "router": L.dense_init(kr, d_model, n_experts, jnp.float32),
        "wi": ei(jax.random.split(ki, n_experts)),  # [E, d, f]
        "wg": ei(jax.random.split(kg, n_experts)),  # [E, d, f]
        "wo": eo(jax.random.split(ko, n_experts)),  # [E, f, d]
    }


def _moe_one_seq(p: dict, xf: Array, *, top_k: int, capacity: int,
                 activation: str) -> tuple[Array, Array]:
    """Dispatch+compute for ONE sequence. xf: [T, d] -> ([T, d], aux).

    Per-sequence dispatch keeps the argsort/gather/scatter local to the
    sequence — under GSPMD the batch axis stays sharded and no token ever
    crosses a shard boundary (the global-sort variant forced all-gathers of
    the whole activation tensor; see §Perf log)."""
    T, d = xf.shape
    E = p["wi"].shape[0]

    logits = (xf.astype(jnp.float32) @ p["router"])  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load balancing aux loss -------------------------------------------
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(expert_ids, E, dtype=jnp.float32)
    ce = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch ------------------------------------------------
    A = T * top_k
    flat_expert = expert_ids.reshape(A)
    flat_token = jnp.repeat(jnp.arange(T), top_k)
    flat_gate = gate_vals.reshape(A)

    order = jnp.argsort(flat_expert)
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    rank = jnp.arange(A) - jnp.searchsorted(sorted_expert, sorted_expert,
                                            side="left")
    keep = rank < capacity
    slot = jnp.where(keep, sorted_expert * capacity + rank, E * capacity)

    buf_tokens = jnp.zeros((E * capacity + 1,), dtype=jnp.int32).at[slot].set(
        sorted_token.astype(jnp.int32), mode="drop")
    buf_gate = jnp.zeros((E * capacity + 1,), dtype=flat_gate.dtype).at[
        slot].set(jnp.where(keep, sorted_gate, 0.0), mode="drop")
    xe = xf[buf_tokens[: E * capacity]].reshape(E, capacity, d)

    # ---- grouped expert matmuls (FLOPs = E*C*d*f, C*E = top_k*T*cf) ---------
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    h = act(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["wi"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])  # [E, C, d]

    ye_flat = (ye.reshape(E * capacity, d)
               * buf_gate[: E * capacity, None].astype(ye.dtype))
    out = jnp.zeros((T, d), dtype=ye.dtype).at[
        buf_tokens[: E * capacity]].add(ye_flat)
    return out.astype(xf.dtype), aux


def _moe_dense(p: dict, x: Array, *, top_k: int,
               activation: str) -> tuple[Array, Array]:
    """Dense-mixture fallback: every expert computed on every token, combined
    with the (renormalized top-k) router weights. Costs E/top_k x the active
    FLOPs but contains NO gather/scatter — it partitions cleanly under GSPMD
    (the sparse dispatch path measures pathologically on the 256-way mesh;
    see EXPERIMENTS.md §Perf Cell B). Numerically identical to the sparse
    path with infinite capacity."""
    B, S, d = x.shape
    E = p["wi"].shape[0]
    logits = x.astype(jnp.float32) @ p["router"]  # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gv, ei = jax.lax.top_k(probs, top_k)
    gv = gv / jnp.sum(gv, axis=-1, keepdims=True)
    gates = jnp.sum(jax.nn.one_hot(ei, E, dtype=jnp.float32)
                    * gv[..., None], axis=2)  # [B, S, E]
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean((gates > 0).astype(jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    h = act(jnp.einsum("bsd,edf->bsef", x, p["wg"])) * jnp.einsum(
        "bsd,edf->bsef", x, p["wi"])
    ye = jnp.einsum("bsef,efd->bsed", h, p["wo"])
    out = jnp.einsum("bsed,bse->bsd", ye, gates.astype(ye.dtype))
    return out.astype(x.dtype), aux


def moe_mlp(p: dict, x: Array, *, top_k: int, capacity_factor: float = 1.25,
            activation: str = "silu", impl: str = "sparse"
            ) -> tuple[Array, Array]:
    """x: [B, S, d] -> (out [B, S, d], aux loss). impl="sparse": vmapped
    per-sequence dispatch (capacity = cf * S * top_k / E per sequence);
    impl="dense": GSPMD-friendly dense mixture (see _moe_dense)."""
    if impl == "dense":
        return _moe_dense(p, x, top_k=top_k, activation=activation)
    B, S, d = x.shape
    E = p["wi"].shape[0]
    capacity = max(top_k, int(capacity_factor * S * top_k / E + 0.5))
    out, aux = jax.vmap(
        lambda xs: _moe_one_seq(p, xs, top_k=top_k, capacity=capacity,
                                activation=activation))(x)
    return out, jnp.mean(aux)
