"""Mamba-2 (SSD — state-space duality) blocks and LM.

Implements the chunked SSD block decomposition from arXiv:2405.21060:
intra-chunk (quadratic within a chunk, dual attention form) + inter-chunk
state recurrence (scan over chunk states). Training/prefill use the chunked
form; decode is the O(1) recurrent state update. The Pallas kernel
(kernels/ssd) implements the same decomposition tiled for VMEM; the functions
here are the XLA path and the oracle the kernel is tested against.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

Array = jax.Array


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_chunked(x: Array, dt: Array, A_log: Array, Bm: Array, Cm: Array,
                chunk: int, initial_state: Array | None = None,
                impl: str = "xla") -> tuple[Array, Array]:
    """Chunked SSD scan.

    x:  [b, s, h, p]   per-head inputs
    dt: [b, s, h]      softplus-ed step sizes
    A_log: [h]         log of -A (per-head scalar decay)
    Bm, Cm: [b, s, n]  input/output projections (single group, broadcast over h)
    -> (y [b, s, h, p], final_state [b, h, p, n])
    """
    if impl == "pallas":
        from repro.kernels.ssd import ops as ssd_ops
        return ssd_ops.ssd(x, dt, A_log, Bm, Cm, chunk, initial_state)

    b, s, h, p = x.shape
    n = Bm.shape[-1]
    nc = s // chunk
    assert s % chunk == 0, f"seq {s} not divisible by ssd chunk {chunk}"
    f32 = jnp.float32

    a = (-jnp.exp(A_log.astype(f32)) * dt.astype(f32))  # [b, s, h] log-decay
    xd = x.astype(f32) * dt.astype(f32)[..., None]  # dt-weighted input

    ac = a.reshape(b, nc, chunk, h)
    xc = xd.reshape(b, nc, chunk, h, p)
    Bc = Bm.astype(f32).reshape(b, nc, chunk, n)
    Cc = Cm.astype(f32).reshape(b, nc, chunk, n)

    cum = jnp.cumsum(ac, axis=2)  # [b, nc, q, h]
    # intra-chunk decay matrix L[i,j] = exp(cum_i - cum_j), i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [b,nc,q,k,h]
    tril = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask in log domain BEFORE exp: the upper triangle holds large positive
    # values whose exp is inf, and inf*0 => NaN in the backward pass.
    diff = jnp.where(tril[None, None, :, :, None], diff, -jnp.inf)
    Lmat = jnp.exp(diff)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)
    # explicit contraction order: a free einsum path may materialize the
    # [b,c,q,k,h,p] product (275 GB at prefill_32k shapes — §Perf log)
    sl = scores[..., None] * Lmat  # [b,nc,q,k,h]
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", sl, xc)

    # per-chunk end states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [b, nc, q, h]
    xde = xc * decay_to_end[..., None]  # [b, nc, q, h, p]
    chunk_states = jnp.einsum("bcqn,bcqhp->bchpn", Bc, xde)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [b, nc, h] total chunk decay

    # inter-chunk recurrence
    s0 = (jnp.zeros((b, h, p, n), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(state, inp):
        cs, cd = inp  # [b,h,p,n], [b,h]
        prev = state
        new = prev * cd[..., None, None] + cs
        return new, prev

    final_state, prev_states = jax.lax.scan(
        step, s0, (chunk_states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [b, nc, h, p, n]

    state_decay = jnp.exp(cum)  # decay from chunk start to position q
    y_off = jnp.einsum("bcqn,bchpn->bcqhp", Cc, prev_states) \
        * state_decay[..., None]

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step(state: Array, x: Array, dt: Array, A_log: Array,
                    Bm: Array, Cm: Array) -> tuple[Array, Array]:
    """O(1) recurrent update. state: [b,h,p,n]; x: [b,h,p]; dt: [b,h];
    Bm, Cm: [b,n] -> (y [b,h,p], new_state)."""
    f32 = jnp.float32
    decay = jnp.exp(-jnp.exp(A_log.astype(f32)) * dt.astype(f32))  # [b,h]
    upd = (dt.astype(f32)[..., None] * x.astype(f32))[..., None] * \
        Bm.astype(f32)[:, None, None, :]
    new_state = state.astype(f32) * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(f32))
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Mamba-2 mixer (in_proj -> conv -> SSD -> gate -> out_proj)
# ---------------------------------------------------------------------------


def mixer_dims(cfg: ModelConfig) -> dict:
    d_inner = cfg.d_inner or 2 * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return dict(d_inner=d_inner, n_heads=n_heads, conv_dim=conv_dim,
                n=cfg.ssm_state, p=cfg.ssm_head_dim)


def init_mamba_mixer(key: Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    dm = mixer_dims(cfg)
    ki, kc, ko, kd = jax.random.split(key, 4)
    d_in_proj = 2 * dm["d_inner"] + 2 * dm["n"] + dm["n_heads"]
    return {
        "in_proj": L.dense_init(ki, cfg.d_model, d_in_proj, dtype),
        "conv": L.init_conv1d(kc, 1, 1, cfg.conv_kernel, dtype) | {
            # depthwise conv over conv_dim channels: w [k, conv_dim]
            "w": (jax.random.normal(kc, (cfg.conv_kernel, dm["conv_dim"]))
                  / math.sqrt(cfg.conv_kernel)).astype(dtype),
            "b": jnp.zeros((dm["conv_dim"],), dtype),
        },
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, dm["n_heads"])).astype(jnp.float32),
        "D": jnp.ones((dm["n_heads"],), jnp.float32),
        "dt_bias": jnp.zeros((dm["n_heads"],), jnp.float32),
        "norm": L.init_rmsnorm(dm["d_inner"], dtype),
        "out_proj": L.dense_init(ko, dm["d_inner"], cfg.d_model, dtype),
    }


def _causal_depthwise_conv(w: Array, b: Array, x: Array,
                           conv_state: Array | None = None):
    """x: [B, S, C]; w: [k, C] depthwise causal. Returns (y, new_state[k-1])."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+k-1, C]
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(y + b[None, None, :]), new_state


def _lora(lp, name, x, cfg):
    if lp is None or name not in lp:
        return 0.0
    a, b = lp[name]["a"], lp[name]["b"]
    return (((x.astype(a.dtype) @ a) @ b) * (cfg.lora_alpha / cfg.lora_rank)
            ).astype(x.dtype)


def mamba_mixer(p: dict, cfg: ModelConfig, x: Array,
                ssm_cache: dict | None = None,
                return_fused_input: bool = False, lp: dict | None = None):
    """x: [B, S, d] -> (y [B, S, d], new_cache).

    ssm_cache = {"conv": [B, k-1, conv_dim], "state": [B, h, p, n]} for decode.
    ``return_fused_input`` exposes the pre-out_proj hidden (RELIEF fusion hook).
    """
    dm = mixer_dims(cfg)
    B_, S, _ = x.shape
    zxbcdt = x @ p["in_proj"] + _lora(lp, "in_proj", x, cfg)
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [dm["d_inner"], 2 * dm["d_inner"], 2 * dm["d_inner"] + dm["n"],
                 2 * dm["d_inner"] + 2 * dm["n"]], axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_state = None if ssm_cache is None else ssm_cache["conv"]
    conv_out, new_conv = _causal_depthwise_conv(p["conv"]["w"], p["conv"]["b"],
                                                conv_in, conv_state)
    xin, Bm, Cm = jnp.split(conv_out, [dm["d_inner"], dm["d_inner"] + dm["n"]],
                            axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,h]
    xh = xin.reshape(B_, S, dm["n_heads"], dm["p"])

    if ssm_cache is None:
        y, final_state = ssd_chunked(xh, dt, p["A_log"], Bm, Cm,
                                     min(cfg.ssd_chunk, S), impl=cfg.attn_impl
                                     if cfg.attn_impl == "pallas" else "xla")
    else:
        yh, final_state = ssd_decode_step(ssm_cache["state"], xh[:, 0],
                                          dt[:, 0], p["A_log"], Bm[:, 0],
                                          Cm[:, 0])
        y = yh[:, None]
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, dm["d_inner"])
    y = L.rmsnorm(p["norm"], y) * jax.nn.silu(z)
    new_cache = (None if ssm_cache is None and final_state is None else
                 {"conv": new_conv, "state": final_state})
    if return_fused_input:
        return y, new_cache
    return y @ p["out_proj"] + _lora(lp, "out_proj", y, cfg), new_cache


# ---------------------------------------------------------------------------
# Mamba-2 LM
# ---------------------------------------------------------------------------


def init_mamba_lora(key: Array, cfg: ModelConfig) -> dict:
    """LoRA on the mixer in/out projections (paper technique on SSM archs;
    DESIGN.md §4: channel groups of in_proj are the block analogue)."""
    dm = mixer_dims(cfg)
    dt = jnp.float32 if cfg.lora_dtype == "float32" else cfg.p_dtype()
    r = cfg.lora_rank
    d_in_proj = 2 * dm["d_inner"] + 2 * dm["n"] + dm["n_heads"]
    shapes = {"in_proj": (cfg.d_model, d_in_proj),
              "out_proj": (dm["d_inner"], cfg.d_model)}

    def one_layer(k):
        out = {}
        for name, (din, dout) in shapes.items():
            k, ka = jax.random.split(k)
            out[name] = {"a": (jax.random.normal(ka, (din, r)) /
                               math.sqrt(din)).astype(dt),
                         "b": jnp.zeros((r, dout), dtype=dt)}
        return out

    return jax.vmap(one_layer)(jax.random.split(key, cfg.n_layers))


def init_mamba_lm(key: Array, cfg: ModelConfig, with_lora: bool = True) -> dict:
    from repro.models.transformer import padded_vocab

    ke, kl, klo = jax.random.split(key, 3)
    dt = cfg.p_dtype()

    def one_layer(k):
        km, = jax.random.split(k, 1)
        return {"mixer": init_mamba_mixer(km, cfg, dt),
                "ln": L.init_rmsnorm(cfg.d_model, dt)}

    params = {"base": {
        "embed": L.embed_init(ke, padded_vocab(cfg), cfg.d_model, dt),
        "layers": jax.vmap(one_layer)(jax.random.split(kl, cfg.n_layers)),
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
    }}
    if with_lora:
        params["lora"] = {"layers": init_mamba_lora(klo, cfg)}
    return params


def mamba_forward(params: dict, cfg: ModelConfig, tokens: Array,
                  caches=None, skip_unembed: bool = False
                  ) -> tuple[Array, Any, Array]:
    from repro.models.transformer import unembed  # shared unembed/tied head

    x = jnp.take(params["base"]["embed"], tokens, axis=0).astype(cfg.runtime_dtype())
    lora_layers = params.get("lora", {}).get("layers")

    def body(x, step):
        p, lp = step
        h = L.rmsnorm(p["ln"], x)
        y, _ = mamba_mixer(p["mixer"], cfg, h, lp=lp)
        return x + y, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, (params["base"]["layers"], lora_layers))
    else:  # unrolled (dry-run accounting)
        for t in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(
                lambda a: a[t], (params["base"]["layers"], lora_layers)))
    x = L.rmsnorm(params["base"]["final_norm"], x)
    if skip_unembed:
        return x, None, jnp.float32(0.0)
    return unembed(params, cfg, x), None, jnp.float32(0.0)


def init_mamba_caches(cfg: ModelConfig, batch: int, max_len: int = 0,
                      dtype=None) -> dict:
    dm = mixer_dims(cfg)
    dtype = dtype or cfg.runtime_dtype()
    Lyr = cfg.n_layers
    return {
        "conv": jnp.zeros((Lyr, batch, cfg.conv_kernel - 1, dm["conv_dim"]), dtype),
        "state": jnp.zeros((Lyr, batch, dm["n_heads"], dm["p"], dm["n"]),
                           jnp.float32),
    }


def mamba_decode_step(params: dict, cfg: ModelConfig, caches: dict,
                      token: Array, pos: Array):
    from repro.models.transformer import unembed

    x = jnp.take(params["base"]["embed"], token, axis=0).astype(cfg.runtime_dtype())
    lora_layers = params.get("lora", {}).get("layers")

    def body(x, step):
        p, lp, cache = step
        h = L.rmsnorm(p["ln"], x)
        y, nc = mamba_mixer(p["mixer"], cfg, h, ssm_cache=cache, lp=lp)
        return x + y, nc

    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(
            body, x, (params["base"]["layers"], lora_layers, caches))
    else:
        ncs = []
        for t in range(cfg.n_layers):
            x, nc = body(x, jax.tree.map(
                lambda a: a[t],
                (params["base"]["layers"], lora_layers, caches)))
            ncs.append(nc)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
    x = L.rmsnorm(params["base"]["final_norm"], x)
    return unembed(params, cfg, x), new_caches
