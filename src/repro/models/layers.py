"""Core neural-net building blocks, pure JAX (no flax).

Every block is a pair of functions:
  init_<block>(key, cfg, ...) -> param pytree (dicts of jnp arrays)
  <block>(params, x, ...)     -> output

Conventions
-----------
* Weights are stored as [in_dim, out_dim] so forward is ``x @ w``.
* Layer-stacked parameters carry a leading [L, ...] axis and are consumed by
  ``jax.lax.scan`` in the model files.
* ``cfg.dtype`` is the activation/compute dtype (bf16 on TPU, fp32 for tiny
  CPU tests); norm statistics and softmax accumulate in fp32.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from collections.abc import Sequence
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key: Array, in_dim: int, out_dim: int, dtype=jnp.float32) -> Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key: Array, vocab: int, dim: int, dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype=jnp.float32) -> Array:
    return jnp.zeros((dim,), dtype=dtype)  # gemma-style (1 + w) parameterization


def rmsnorm(w: Array, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def init_layernorm(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype=dtype), "bias": jnp.zeros((dim,), dtype=dtype)}


def layernorm(p: dict, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)  # [head_dim/2]


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# soft capping (gemma-2)
# ---------------------------------------------------------------------------


def softcap(x: Array, cap: float | None) -> Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int


def init_attention(key: Array, dims: AttnDims, dtype=jnp.float32) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, k, hd = dims.d_model, dims.n_heads, dims.n_kv_heads, dims.head_dim
    return {
        "wq": dense_init(kq, d, h * hd, dtype),
        "wk": dense_init(kk, d, k * hd, dtype),
        "wv": dense_init(kv, d, k * hd, dtype),
        "wo": dense_init(ko, h * hd, d, dtype),
    }


def _chunked_attention(
    q: Array,  # [B, S, K, G, hd]  (G = heads per kv group)
    k: Array,  # [B, T, K, hd]
    v: Array,  # [B, T, K, hd]
    q_positions: Array,  # [S] or [B, S] absolute positions of queries
    kv_positions: Array,  # [T] or [B, T] positions of keys (−1 ⇒ empty slot)
    window: Array | int | None,  # sliding window size (tokens), None = global
    attn_softcap_val: float | None,
    q_chunk: int,
) -> Array:
    """Causal (optionally sliding-window) attention, chunked over queries.

    Never materializes the full [S, T] score matrix — peak live memory is
    [B, q_chunk, K, G, T] per chunk, which bounds compile-time memory analysis
    at 32k prefill. FLOPs are identical to the naive einsum. Works for decode
    (S=1) and prefill (S=T) alike. Positions may carry a batch axis — the
    continuous-batching serve path decodes rows sitting at different
    sequence positions in one step.
    """
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    if window is None:
        window = jnp.array(np.iinfo(np.int32).max, dtype=jnp.int32)
    window = jnp.asarray(window, dtype=jnp.int32)

    q_chunk = min(q_chunk, S)
    n_chunks = S // q_chunk
    assert S % q_chunk == 0, f"S={S} not divisible by q_chunk={q_chunk}"

    qp = q_positions if q_positions.ndim == 2 else q_positions[None]
    qp = jnp.broadcast_to(qp, (B, S))
    kvp = kv_positions if kv_positions.ndim == 2 else kv_positions[None]
    kvp = jnp.broadcast_to(kvp, (B, T))

    qr = q.reshape(B, n_chunks, q_chunk, K, G, hd)
    qpr = qp.reshape(B, n_chunks, q_chunk)

    def one_chunk(qc, qpos):
        # qc: [B, qc, K, G, hd]; qpos: [B, qc]
        s = jnp.einsum("bqkgh,btkh->bqkgt", qc.astype(jnp.float32) * scale,
                       k.astype(jnp.float32))
        s = softcap(s, attn_softcap_val)
        valid = kvp >= 0  # [B, T]
        causal = qpos[:, :, None] >= kvp[:, None, :]  # [B, qc, T]
        in_window = (qpos[:, :, None] - kvp[:, None, :]) < window
        mask = (causal & in_window & valid[:, None, :])[:, :, None, None, :]
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bqkgt,btkh->bqkgh", p, v.astype(jnp.float32))
        return o.astype(q.dtype)

    if n_chunks == 1:
        out = one_chunk(qr[:, 0], qpr[:, 0])[:, None]
    else:
        out = jax.lax.map(lambda args: one_chunk(*args),
                          (qr.transpose(1, 0, 2, 3, 4, 5),
                           qpr.transpose(1, 0, 2)))
        out = out.transpose(1, 0, 2, 3, 4, 5)
    return out.reshape(B, S, K, G, hd)


def attention(
    p: dict,
    dims: AttnDims,
    x: Array,  # [B, S, d]
    positions: Array,  # [S]
    *,
    kv_cache: dict | None = None,  # {"k","v": [B, T, K, hd], "pos": [T]}
    window: Array | int | None = None,
    rope_theta: float = 10000.0,
    attn_softcap_val: float | None = None,
    query_scale: float | None = None,
    q_chunk: int = 1024,
    attn_impl: str = "xla",
) -> tuple[Array, dict | None]:
    """Multi-head attention with GQA, RoPE, sliding window and softcap.

    When ``kv_cache`` is given, the new k/v are written at ``positions`` within
    the cache ring and attention runs over the cache (decode / chunked
    prefill); otherwise self-attention over ``x`` (training / full prefill).
    Returns (output, updated_cache).
    """
    B, S, d = x.shape
    H, K, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    G = H // K

    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, K, hd)
    v = (x @ p["wv"]).reshape(B, S, K, hd)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    if query_scale is not None:
        # e.g. gemma-2 query_pre_attn_scalar: replaces the default 1/sqrt(hd)
        q = q * (query_scale * math.sqrt(hd))

    new_cache = None
    if kv_cache is None:
        kk, vv, kv_pos = k, v, positions
    else:
        T = kv_cache["k"].shape[1]
        slots = positions % T  # ring buffer (rolling window when T == window)
        kk = kv_cache["k"].at[:, slots].set(k)
        vv = kv_cache["v"].at[:, slots].set(v)
        kv_pos = kv_cache["pos"].at[slots].set(positions)
        new_cache = {"k": kk, "v": vv, "pos": kv_pos}

    qg = q.reshape(B, S, K, G, hd)
    if attn_impl == "pallas":  # TPU deployment path (tests use interpret mode)
        from repro.kernels.flash_attention import ops as fa_ops

        o = fa_ops.flash_attention(qg, kk, vv, positions, kv_pos, window,
                                   attn_softcap_val)
    else:
        o = _chunked_attention(qg, kk, vv, positions, kv_pos, window,
                               attn_softcap_val, q_chunk)
    o = o.reshape(B, S, H * hd)
    return o @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_glu_mlp(key: Array, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    ki, kg, ko = jax.random.split(key, 3)
    return {
        "wi": dense_init(ki, d_model, d_ff, dtype),
        "wg": dense_init(kg, d_model, d_ff, dtype),
        "wo": dense_init(ko, d_ff, d_model, dtype),
    }


def glu_mlp(p: dict, x: Array, activation: str = "silu", hint=None) -> Array:
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[activation]
    g, u = x @ p["wg"], x @ p["wi"]
    if hint is not None:  # TP: hidden dim sharded over `model`
        g, u = hint(g), hint(u)
    return (act(g) * u) @ p["wo"]


# ---------------------------------------------------------------------------
# cross-entropy
# ---------------------------------------------------------------------------


def cross_entropy_logits(logits: Array, labels: Array, mask: Array | None = None) -> Array:
    """Mean token cross-entropy; logits [..., V], labels [...] int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# 1-D CNN encoder (paper Backbone 1)
# ---------------------------------------------------------------------------


def init_conv1d(key: Array, in_ch: int, out_ch: int, ksize: int, dtype=jnp.float32) -> dict:
    scale = 1.0 / math.sqrt(in_ch * ksize)
    w = jax.random.normal(key, (ksize, in_ch, out_ch)) * scale
    return {"w": w.astype(dtype), "b": jnp.zeros((out_ch,), dtype=dtype)}


def conv1d(p: dict, x: Array, stride: int = 1) -> Array:
    """x: [B, T, C_in] -> [B, T', C_out] (SAME padding)."""
    out = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"))
    return out + p["b"]
