from repro.models.api import (decode_step, forward, init_caches, init_model,
                              loss_fn, param_count)
