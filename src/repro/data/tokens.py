"""Synthetic LM token streams for backbone training/serving examples.

A small order-2 mixture process gives learnable structure (so example losses
visibly fall) without any external corpus.
"""
from __future__ import annotations

import numpy as np


def synthetic_token_batches(vocab: int, batch: int, seq_len: int, steps: int,
                            seed: int = 0, n_codebooks: int = 0):
    rng = np.random.default_rng(seed)
    # order-1 Markov chain with sparse rows -> predictable structure
    k = min(vocab, 8)
    nxt = rng.integers(0, vocab, size=(vocab, k))
    for s in range(steps):
        shape = (batch, seq_len + 1)
        toks = np.zeros(shape, np.int32)
        toks[:, 0] = rng.integers(0, vocab, size=batch)
        choices = rng.integers(0, k, size=shape)
        for tpos in range(1, seq_len + 1):
            toks[:, tpos] = nxt[toks[:, tpos - 1], choices[:, tpos]]
        if n_codebooks:
            cb = np.stack([(toks + 7 * c) % vocab for c in range(n_codebooks)],
                          axis=-1)
            yield {"tokens": cb[:, :-1], "labels": cb[:, 1:]}
        else:
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
