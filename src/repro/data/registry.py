"""Dataset provider registry — the pluggable data side of the scenario matrix.

Every experiment used to hardcode ``make_har_dataset`` + ``mm_config_for``;
this module extracts the implicit contract into a ``DatasetProvider``
protocol (modalities, splits, client batch sampling, model config) and a
name-keyed registry, so PAMAP2/MHEALTH-shaped loaders and the UCF101-style
A+V scenario plug into the engines without touching engine code:

    provider = get_provider("ucf101_av")
    ds = provider.build(seed=0, n_clients=16)
    cfg = provider.mm_config(backbone="cnn", small=True)

``make_har_dataset`` remains the implementation of the two HAR presets; here
they are simply registered providers alongside the synthetic audio+video
scenario (fed-multimodal's UCF101 A+V surface: two modalities with a wide
channel-count gap, 10 action classes).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data import har
from repro.data.har import HARDataset, ModalityDef

try:  # Protocol is typing-only; keep import local failures impossible
    from typing import Protocol, runtime_checkable
except ImportError:  # pragma: no cover
    Protocol = object  # type: ignore

    def runtime_checkable(x):  # type: ignore
        return x


# model-size presets shared by benchmarks/ and sim/scenarios.py (previously
# copy-pasted into every bench _build block)
SIZE_PRESETS = {
    ("cnn", True): dict(d_feat=16, d_fused=64, cnn_ch=(16, 32)),
    ("cnn", False): dict(d_feat=32, d_fused=128, cnn_ch=(32, 64)),
    ("transformer", True): dict(d_feat=16, d_fused=64, enc_layers=2,
                                enc_d=32, enc_ff=64),
    ("transformer", False): dict(d_feat=32, d_fused=128, enc_layers=4,
                                 enc_d=128, enc_ff=256),
}


@runtime_checkable
class DatasetProvider(Protocol):
    """What the engines need from a dataset source.

    ``build`` returns a split container with per-client ``train_x/train_y/
    test_x/test_y`` lists plus ``n_classes``/``modalities`` (the HARDataset
    surface); ``mm_config`` returns the matching model config;
    ``client_batches`` samples stacked local-training batches.
    """
    name: str

    def modalities(self) -> tuple[ModalityDef, ...]: ...

    def n_classes(self) -> int: ...

    def build(self, *, windows_per_subject: int = 240,
              test_frac: float = 0.25, seed: int = 0,
              n_clients: int | None = None,
              alpha: float = 1.0) -> HARDataset: ...

    def mm_config(self, backbone: str = "cnn", small: bool = True,
                  **overrides): ...

    def client_batches(self, x: np.ndarray, y: np.ndarray, batch: int,
                       steps: int, rng: np.random.Generator) -> dict: ...


@dataclasses.dataclass(frozen=True)
class SyntheticProvider:
    """Spec-driven synthetic provider (har.synthesize_dataset under any
    modality/class/subject tuple)."""
    name: str
    mods: tuple[ModalityDef, ...]
    classes: int
    default_subjects: int

    def modalities(self) -> tuple[ModalityDef, ...]:
        return self.mods

    def n_classes(self) -> int:
        return self.classes

    def build(self, *, windows_per_subject: int = 240,
              test_frac: float = 0.25, seed: int = 0,
              n_clients: int | None = None,
              alpha: float = 1.0) -> HARDataset:
        return har.synthesize_dataset(
            self.name, self.mods, self.classes,
            n_clients or self.default_subjects,
            windows_per_subject=windows_per_subject, test_frac=test_frac,
            seed=seed, alpha=alpha)

    def mm_config(self, backbone: str = "cnn", small: bool = True,
                  **overrides):
        from repro.models.multimodal import MMConfig, ModalitySpec

        kw = dict(SIZE_PRESETS[(backbone, small)]) | overrides
        d_feat = kw.pop("d_feat")
        mods = tuple(ModalitySpec(m.name, m.channels,
                                  d_feat if m.kind == "imu" else d_feat // 2)
                     for m in self.mods)
        return MMConfig(name=self.name, modalities=mods,
                        n_classes=self.classes, backbone=backbone, **kw)

    def client_batches(self, x: np.ndarray, y: np.ndarray, batch: int,
                       steps: int, rng: np.random.Generator) -> dict:
        return har.client_batches(x, y, batch, steps, rng)


_PROVIDERS: dict[str, DatasetProvider] = {}


def register_provider(provider: DatasetProvider) -> DatasetProvider:
    """Add (or replace) a provider under ``provider.name``."""
    _PROVIDERS[provider.name] = provider
    return provider


def get_provider(name: str) -> DatasetProvider:
    if name not in _PROVIDERS:
        raise KeyError(f"unknown dataset provider {name!r}; "
                       f"registered: {provider_names()}")
    return _PROVIDERS[name]


def provider_names() -> list[str]:
    return sorted(_PROVIDERS)


# --- built-in providers ------------------------------------------------------

for _name, _spec in har.DATASETS.items():
    register_provider(SyntheticProvider(_name, _spec["modalities"],
                                        _spec["n_classes"],
                                        _spec["n_subjects"]))

# UCF101-style A+V: a high-rate "video" feature stream (harmonic-rich, like
# the IMU generator) next to a sparse spiky "audio" track — the two-modality,
# wide-channel-gap shape of fed-multimodal's UCF101 split, 10 action classes
register_provider(SyntheticProvider(
    "ucf101_av",
    (ModalityDef("video", 12, "imu"), ModalityDef("audio", 2, "ecg")),
    classes=10, default_subjects=16))
