"""Synthetic PAMAP2 / MHEALTH lookalike datasets (offline container — see
DESIGN.md §9 for the deviation note).

Faithful surface statistics: 4 sensor modalities at 50 Hz, 5.12 s windows of
256 samples (paper VI-A1), 12 activity classes, subject-partitioned non-IID
clients (8 for PAMAP2, 10 for MHEALTH). Signals are class-conditional
harmonic mixtures with modality-specific character (IMU: movement-band
harmonics; HR: slow drift around a class-dependent level; ECG: periodic
spikes) plus *subject* effects (gain/phase/noise/class-prior skew) so client
distributions are genuinely non-IID.
"""
from __future__ import annotations

import dataclasses

import numpy as np

WINDOW = 256
RATE_HZ = 50.0


@dataclasses.dataclass(frozen=True)
class ModalityDef:
    name: str
    channels: int
    kind: str  # imu | pulse | ecg


DATASETS = {
    "pamap2": {
        "modalities": (ModalityDef("acc", 3, "imu"), ModalityDef("gyro", 3, "imu"),
                       ModalityDef("mag", 3, "imu"), ModalityDef("hr", 1, "pulse")),
        "n_subjects": 8, "n_classes": 12,
    },
    "mhealth": {
        "modalities": (ModalityDef("acc", 3, "imu"), ModalityDef("gyro", 3, "imu"),
                       ModalityDef("mag", 3, "imu"), ModalityDef("ecg", 2, "ecg")),
        "n_subjects": 10, "n_classes": 12,
    },
}


@dataclasses.dataclass
class HARDataset:
    name: str
    train_x: list[np.ndarray]  # per-subject [n, WINDOW, C]
    train_y: list[np.ndarray]
    test_x: list[np.ndarray]
    test_y: list[np.ndarray]
    n_classes: int
    modalities: tuple[ModalityDef, ...]

    @property
    def n_subjects(self) -> int:
        return len(self.train_x)

    def channels(self) -> int:
        return sum(m.channels for m in self.modalities)


def _modality_signal(kind: str, cls: int, n_ch: int, n: int, t: np.ndarray,
                     rng: np.random.Generator, gain: float, phase: float,
                     noise: float) -> np.ndarray:
    """-> [n, WINDOW, n_ch] class-conditional signals."""
    out = np.zeros((n, WINDOW, n_ch), np.float32)
    base_f = 0.6 + 0.37 * cls  # class-dependent fundamental (Hz)
    for ch in range(n_ch):
        ph = rng.uniform(0, 2 * np.pi, size=(n, 1)) + phase + 0.9 * ch
        if kind == "imu":
            f1 = base_f * (1.0 + 0.11 * ch)
            sig = (np.sin(2 * np.pi * f1 * t[None] + ph)
                   + 0.5 * np.sin(2 * np.pi * 2 * f1 * t[None] + 1.7 * ph)
                   + 0.25 * np.sin(2 * np.pi * 3.1 * f1 * t[None]))
            amp = 1.0 + 0.3 * cls
        elif kind == "pulse":  # heart rate: class-dependent level + slow drift
            level = (55.0 + 7.0 * cls) / 100.0
            sig = level + 0.08 * np.sin(2 * np.pi * 0.08 * (1 + 0.2 * cls)
                                        * t[None] + ph)
            amp = 1.0
        else:  # ecg: periodic spike train, rate grows with class
            rate = 1.0 + 0.15 * cls  # beats/s
            carrier = np.sin(2 * np.pi * rate * t[None] + ph)
            sig = np.exp(-30.0 * (1 - carrier)) + 0.1 * np.sin(
                2 * np.pi * 0.3 * t[None] + ph)
            amp = 1.0
        out[..., ch] = gain * amp * sig
    out += rng.normal(0, noise, size=out.shape).astype(np.float32)
    return out


def synthesize_dataset(name: str, modalities: tuple[ModalityDef, ...],
                       n_classes: int, n_subjects: int,
                       windows_per_subject: int = 240,
                       test_frac: float = 0.25, seed: int = 0,
                       alpha: float = 1.0) -> HARDataset:
    """Spec-driven synthesis: any (modalities, n_classes, n_subjects) tuple
    gets the same class-conditional + subject-effect generative process, so
    dataset providers beyond the two HAR presets (data/registry.py) plug in
    without touching this module. ``alpha``: Dirichlet concentration of
    per-subject class priors (non-IID)."""
    mods = modalities
    n_subj = n_subjects
    rng = np.random.default_rng(seed)
    t = np.arange(WINDOW, dtype=np.float32) / RATE_HZ

    tr_x, tr_y, te_x, te_y = [], [], [], []
    for s in range(n_subj):
        prior = rng.dirichlet(alpha * np.ones(n_classes))
        gain = float(np.exp(rng.normal(0, 0.1)))
        phase = float(rng.uniform(0, 2 * np.pi))
        noise = float(rng.uniform(0.12, 0.3))
        counts = rng.multinomial(windows_per_subject, prior)
        xs, ys = [], []
        for cls, cnt in enumerate(counts):
            if cnt == 0:
                continue
            parts = [_modality_signal(m.kind, cls, m.channels, cnt, t, rng,
                                      gain, phase, noise) for m in mods]
            xs.append(np.concatenate(parts, axis=-1))
            ys.append(np.full(cnt, cls, np.int32))
        x = np.concatenate(xs)
        y = np.concatenate(ys)
        perm = rng.permutation(len(y))
        x, y = x[perm], y[perm]
        n_te = max(1, int(test_frac * len(y)))
        te_x.append(x[:n_te])
        te_y.append(y[:n_te])
        tr_x.append(x[n_te:])
        tr_y.append(y[n_te:])
    return HARDataset(name, tr_x, tr_y, te_x, te_y, n_classes, mods)


def make_har_dataset(name: str, windows_per_subject: int = 240,
                     test_frac: float = 0.25, seed: int = 0,
                     n_subjects: int | None = None,
                     alpha: float = 1.0) -> HARDataset:
    """The two paper presets (PAMAP2 / MHEALTH lookalikes), registered as
    dataset providers in data/registry.py."""
    spec = DATASETS[name]
    return synthesize_dataset(name, spec["modalities"], spec["n_classes"],
                              n_subjects or spec["n_subjects"],
                              windows_per_subject=windows_per_subject,
                              test_frac=test_frac, seed=seed, alpha=alpha)


def mm_config_for(name: str, backbone: str = "cnn", d_feat: int = 32,
                  **overrides):
    """Build the paper's MMConfig for a dataset."""
    from repro.models.multimodal import MMConfig, ModalitySpec

    spec = DATASETS[name]
    mods = tuple(ModalitySpec(m.name, m.channels,
                              d_feat if m.kind == "imu" else d_feat // 2)
                 for m in spec["modalities"])
    return MMConfig(name=name, modalities=mods, n_classes=spec["n_classes"],
                    backbone=backbone, **overrides)


def client_batches(x: np.ndarray, y: np.ndarray, batch: int, steps: int,
                   rng: np.random.Generator) -> dict:
    """Sample [steps, batch] with replacement -> stacked jnp-ready arrays."""
    idx = rng.integers(0, len(y), size=(steps, batch))
    return {"x": x[idx], "y": y[idx]}
