from repro.data.har import (DATASETS, HARDataset, ModalityDef, client_batches,
                            make_har_dataset, mm_config_for,
                            synthesize_dataset)
from repro.data.registry import (DatasetProvider, SyntheticProvider,
                                 get_provider, provider_names,
                                 register_provider)
from repro.data.tokens import synthetic_token_batches
