from repro.data.har import (DATASETS, HARDataset, client_batches,
                            make_har_dataset, mm_config_for)
from repro.data.tokens import synthetic_token_batches
