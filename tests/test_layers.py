"""Unit tests for the model building blocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L

KEY = jax.random.PRNGKey(0)


def test_rmsnorm_unit_scale():
    w = L.init_rmsnorm(16)
    x = jax.random.normal(KEY, (4, 16)) * 10.0
    y = L.rmsnorm(w, x)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), -1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-5)


def test_layernorm_stats():
    p = L.init_layernorm(32)
    x = jax.random.normal(KEY, (8, 32)) * 3 + 2
    y = L.layernorm(p, x)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.std(y, -1)), 1.0, atol=1e-2)


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(KEY, (1, 8, 2, 16))
    pos = jnp.arange(8)
    y = L.apply_rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # dot products depend only on relative distance
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 1, 16))
    # same content placed at shifted positions
    qr1 = L.apply_rope(q, pos)
    kr1 = L.apply_rope(k, pos)
    qr2 = L.apply_rope(q, pos + 13)
    kr2 = L.apply_rope(k, pos + 13)
    d1 = jnp.einsum("bshd,bshd->bsh", qr1, kr1)
    d2 = jnp.einsum("bshd,bshd->bsh", qr2, kr2)
    # atol floors the comparison for near-zero dot products (f32 rotations)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-4,
                               atol=1e-5)


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = L.softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    np.testing.assert_allclose(np.asarray(L.softcap(x, None)), np.asarray(x))
    # near-linear for small inputs
    small = jnp.linspace(-1, 1, 11)
    np.testing.assert_allclose(np.asarray(L.softcap(small, 50.0)),
                               np.asarray(small), atol=1e-3)


@pytest.mark.parametrize("q_chunk", [8, 16, 64])
@pytest.mark.parametrize("window", [None, 16])
def test_chunked_attention_matches_naive(q_chunk, window):
    B, S, K, G, hd = 2, 64, 2, 2, 8
    q = jax.random.normal(KEY, (B, S, K, G, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, K, hd))
    pos = jnp.arange(S)
    out = L._chunked_attention(q, k, v, pos, pos, window, None, q_chunk)
    from repro.kernels.flash_attention.ref import flash_attention_ref
    ref = flash_attention_ref(q, k, v, pos, pos,
                              window or np.iinfo(np.int32).max, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_cross_entropy_matches_manual():
    logits = jax.random.normal(KEY, (5, 11))
    labels = jnp.array([0, 3, 10, 2, 7])
    got = L.cross_entropy_logits(logits, labels)
    p = jax.nn.log_softmax(logits)
    want = -jnp.mean(p[jnp.arange(5), labels])
    np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


def test_cross_entropy_mask():
    logits = jax.random.normal(KEY, (4, 7))
    labels = jnp.array([1, 2, 3, 4])
    mask = jnp.array([1.0, 1.0, 0.0, 0.0])
    got = L.cross_entropy_logits(logits, labels, mask)
    want = L.cross_entropy_logits(logits[:2], labels[:2])
    np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


def test_conv1d_causality_and_shape():
    p = L.init_conv1d(KEY, 3, 5, 3)
    x = jax.random.normal(KEY, (2, 16, 3))
    y = L.conv1d(p, x, stride=2)
    assert y.shape == (2, 8, 5)
