"""Fleet fault-injection tests (sim/faults.py + the async runtimes):
deterministic seeded draws, per-cohort attack targeting, corruption
semantics, dropout accounting, and composition with population churn."""
import jax
import numpy as np
import pytest

from repro.core.async_engine import (AsyncFedConfig, AsyncFedRun,
                                     VectorizedAsyncFedRun)
from repro.core.strategies import async_relief
from repro.core.tasks import MMTask
from repro.data import make_har_dataset, mm_config_for
from repro.sim import FaultModel, FaultRuntime, make_fleet, scale_fleet

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    ds = make_har_dataset("pamap2", windows_per_subject=60, seed=0)
    cfg = mm_config_for("pamap2", backbone="cnn", d_feat=8, d_fused=32,
                        cnn_ch=(8, 16))
    task, tr0 = MMTask.create(cfg, KEY)
    return ds, task, tr0


def _tree(rng, k):
    return {"a": rng.standard_normal((k, 6, 3)).astype(np.float32),
            "b": rng.standard_normal((k, 4)).astype(np.float32)}


# ---------------------------------------------------------------------------
# FaultModel: membership, draws, corruption
# ---------------------------------------------------------------------------


def test_validation():
    with pytest.raises(ValueError, match="corruption"):
        FaultModel(corruption="bogus")
    with pytest.raises(ValueError, match="byzantine_frac"):
        FaultModel(byzantine_frac=1.5)
    assert not FaultModel().active
    assert FaultModel(byzantine_frac=0.1).active


def test_byzantine_mask_deterministic_and_sized():
    mm = np.random.default_rng(0).random((200, 4)) > 0.5
    fm = FaultModel(seed=11, byzantine_frac=0.25)
    m1, m2 = fm.byzantine_mask(mm), fm.byzantine_mask(mm)
    np.testing.assert_array_equal(m1, m2)
    assert m1.sum() == round(0.25 * 200)
    assert FaultModel(seed=12, byzantine_frac=0.25).byzantine_mask(
        mm).sum() == m1.sum()  # same budget, different membership
    assert (FaultModel(seed=12, byzantine_frac=0.25).byzantine_mask(mm)
            != m1).any()


def test_targeting_restricts_to_modality_possessors():
    """target_modality concentrates the attacker budget inside one
    modality's aggregation cohort — the rare-cohort attack."""
    mm = np.random.default_rng(1).random((300, 4)) > 0.7  # modalities rare
    fm = FaultModel(seed=5, byzantine_frac=0.5, target_modality=2)
    byz = fm.byzantine_mask(mm)
    assert byz.sum() == round(0.5 * mm[:, 2].sum())
    assert not byz[~mm[:, 2]].any()  # only possessors of m=2 are attackers


def test_cycle_faults_counter_based():
    """A cycle's fate is a pure function of (seed, client, ticket): batch
    composition and call order never change a draw, and honest clients
    never fault."""
    fm = FaultModel(seed=7, byzantine_frac=1.0, dropout_prob=0.5,
                    stall_prob=0.5, stall_factor=3.0)
    byz = np.array([True, True, False, True])
    clients = np.arange(4)
    d1, s1 = fm.cycle_faults(byz, clients, np.zeros(4, np.int64))
    d2, s2 = fm.cycle_faults(byz, clients, np.zeros(4, np.int64))
    np.testing.assert_array_equal(d1, d2)
    np.testing.assert_array_equal(s1, s2)
    assert not d1[2] and s1[2] == 1.0  # honest row untouched
    # permuted batch: per-client outcomes move with the client
    perm = np.array([3, 0, 2, 1])
    dp, sp = fm.cycle_faults(byz, clients[perm], np.zeros(4, np.int64))
    np.testing.assert_array_equal(dp, d1[perm])
    np.testing.assert_array_equal(sp, s1[perm])
    # different ticket => an independent draw exists somewhere in 32 cycles
    draws = [fm.cycle_faults(byz, clients, np.full(4, t, np.int64))[0]
             for t in range(32)]
    assert any((d != d1).any() for d in draws)


def test_corrupt_stack_sign_flip_and_rows():
    rng = np.random.default_rng(2)
    t = _tree(rng, 5)
    fm = FaultModel(corruption="sign_flip", corruption_scale=2.0,
                    byzantine_frac=0.4)
    rows = np.array([False, True, False, False, True])
    out = fm.corrupt_stack(t, rows, np.arange(5), np.zeros(5, np.int64))
    for k in t:
        np.testing.assert_allclose(np.asarray(out[k])[rows], -2.0 * t[k][rows])
        np.testing.assert_allclose(np.asarray(out[k])[~rows], t[k][~rows])


def test_corrupt_stack_collusion_shared_direction():
    """All colluders push one identical direction, stable across cycles and
    batches — the coordinated attack robust mean-rules are weakest against."""
    rng = np.random.default_rng(3)
    fm = FaultModel(seed=9, corruption="collusion", corruption_scale=1.0,
                    byzantine_frac=0.5)
    rows = np.array([True, True, False])
    o1 = fm.corrupt_stack(_tree(rng, 3), rows, np.arange(3),
                          np.zeros(3, np.int64))
    o2 = fm.corrupt_stack(_tree(rng, 3), rows, np.arange(3),
                          np.full(3, 17, np.int64))
    for k in o1:
        a = np.asarray(o1[k])
        np.testing.assert_array_equal(a[0], a[1])  # colluders agree
        np.testing.assert_array_equal(a[:2], np.asarray(o2[k])[:2])  # stable


def test_corrupt_stack_gauss_batch_invariant():
    """Gaussian blow-up noise is keyed by (client, ticket): the same cycle
    corrupted in a different batch gets a bit-identical payload."""
    rng = np.random.default_rng(4)
    t = _tree(rng, 4)
    fm = FaultModel(seed=1, corruption="gauss", corruption_scale=3.0,
                    byzantine_frac=1.0)
    full = fm.corrupt_stack(t, np.ones(4, bool), np.arange(4),
                            np.arange(4, dtype=np.int64))
    solo = fm.corrupt_stack(
        jax.tree.map(lambda x: x[2:3], t), np.ones(1, bool),
        np.array([2]), np.array([2], np.int64))
    for k in t:
        np.testing.assert_array_equal(np.asarray(full[k])[2],
                                      np.asarray(solo[k])[0])


def test_fault_runtime_tickets_advance():
    mm = np.ones((6, 2), bool)
    fx = FaultRuntime(FaultModel(byzantine_frac=0.5, dropout_prob=0.5), mm)
    _, _, byz_rows, t0 = fx.on_dispatch(np.array([0, 3, 5]))
    np.testing.assert_array_equal(t0, 0)
    np.testing.assert_array_equal(byz_rows, fx.byz[[0, 3, 5]])
    _, _, _, t1 = fx.on_dispatch(np.array([3, 4]))
    np.testing.assert_array_equal(t1, [1, 0])  # per-client counters


# ---------------------------------------------------------------------------
# runtime integration: dropout accounting + churn composition
# ---------------------------------------------------------------------------


def test_dropout_slows_progress_not_accounting(setup):
    """Dropped completions are pure loss: same absorbed-update total, more
    simulated time, and no energy/updates accrued for the crashes."""
    ds, task, tr0 = setup
    fleet = scale_fleet(make_fleet(3, 3, 2, M=4), 60,
                        np.random.default_rng(7))
    kw = dict(rounds=1, local_epochs=1, steps_per_epoch=1, batch_size=4,
              eval_every=0, seed=0)
    runs = {}
    for name, fm in (("clean", None),
                     ("faulty", FaultModel(byzantine_frac=0.5,
                                           dropout_prob=0.6,
                                           corruption="none"))):
        run = AsyncFedRun.create(task, tr0, async_relief(buffer_size=8),
                                 fleet, AsyncFedConfig(faults=fm, **kw))
        run.run(ds, total_updates=90)
        runs[name] = run
    assert runs["clean"].trace.completions == 90
    assert runs["faulty"].trace.completions == 90  # absorbed, not attempted
    # crashes burn wall-clock: same work takes longer under dropout
    assert (runs["faulty"].state.sim_time > runs["clean"].state.sim_time)
    byz = runs["faulty"].fx.byz
    per = runs["faulty"].trace.per_client_updates
    # honest clients are untouched by the fault layer's accounting
    assert per[~byz].sum() > 0


def test_dropout_composes_with_churn_invariants(setup):
    """Fault-injected dropout and population churn cancel through disjoint
    mechanisms (skip-absorb vs FleetState.lost) — no double-cancel: every
    absorbed completion counts exactly once and the in-flight counter always
    equals the number of scheduled completions."""
    _, task, tr0 = setup
    fleet = scale_fleet(make_fleet(3, 3, 2, M=4), 500,
                        np.random.default_rng(3))
    fm = FaultModel(seed=2, byzantine_frac=0.4, dropout_prob=0.5,
                    stall_prob=0.3, stall_factor=3.0, corruption="none")
    for fed_kw in ({"churn_rate": 0.5},
                   {"churn_rate": 0.5, "arrival_rate": 0.5}):
        run = VectorizedAsyncFedRun.create(
            task, tr0, async_relief(buffer_size=64), fleet,
            AsyncFedConfig(rounds=1, local_epochs=1, steps_per_epoch=1,
                           batch_size=4, eval_every=0, seed=0,
                           grad_mode="none", jitter_sigma=0.1, faults=fm,
                           **fed_kw))
        run.run(None, total_updates=1500)
        fs = run.fstate
        assert run.trace.completions == 1500, fed_kw
        assert fs.in_flight == int(np.isfinite(fs.t_next).sum()), fed_kw
        assert fs.in_flight <= int(fs.alive.sum()), fed_kw
        assert fs.updates.sum() == 1500, fed_kw


def test_stall_factor_stretches_sim_time(setup):
    """Stalled cycles multiply compute time: the same absorbed-update budget
    takes strictly longer and costs strictly more energy."""
    _, task, tr0 = setup
    fleet = scale_fleet(make_fleet(3, 3, 2, M=4), 200,
                        np.random.default_rng(3))
    kw = dict(rounds=1, local_epochs=1, steps_per_epoch=1, batch_size=4,
              eval_every=0, seed=0, grad_mode="none")
    out = {}
    for name, fm in (("clean", None),
                     ("stalled", FaultModel(byzantine_frac=0.5,
                                            stall_prob=0.8, stall_factor=10.0,
                                            corruption="none"))):
        run = VectorizedAsyncFedRun.create(
            task, tr0, async_relief(buffer_size=32), fleet,
            AsyncFedConfig(faults=fm, **kw))
        run.run(None, total_updates=600)
        out[name] = run
    assert out["stalled"].state.sim_time > out["clean"].state.sim_time
    assert out["stalled"].trace.energy_j > out["clean"].trace.energy_j
