"""Event-driven async runtime tests: sync parity, straggler decoupling,
cohort freezing, and the streaming cohort-agg reduction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as AG
from repro.core import divergence as DV
from repro.core import mdlora
from repro.core.async_engine import AsyncFedConfig, AsyncFedRun
from repro.core.engine import FedConfig, FedRun
from repro.core.strategies import (async_accessible, async_fedbuff,
                                   async_relief, get_strategy)
from repro.core.tasks import MMTask
from repro.data import make_har_dataset, mm_config_for
from repro.sim import make_fleet
from repro.sim.events import EventQueue

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    ds = make_har_dataset("pamap2", windows_per_subject=60, seed=0)
    cfg = mm_config_for("pamap2", backbone="cnn", d_feat=8, d_fused=32,
                        cnn_ch=(8, 16))
    task, tr0 = MMTask.create(cfg, KEY)
    return ds, task, tr0


# ---------------------------------------------------------------------------
# event queue
# ---------------------------------------------------------------------------


def test_event_queue_deterministic_fifo_ties():
    q = EventQueue()
    q.push(2.0, client=0)
    q.push(1.0, client=1)
    q.push(1.0, client=2)
    q.push(1.5, client=3)
    batch = q.pop_simultaneous()
    assert [e.client for e in batch] == [1, 2]  # FIFO within the tie
    assert [e.client for e in q.drain()] == [3, 0]


# ---------------------------------------------------------------------------
# sync parity: the anchor for everything else
# ---------------------------------------------------------------------------


def test_parity_with_sync_engine(setup):
    """Homogeneous fleet + buffer K=N + zero staleness discount must
    reproduce the synchronous engine's global trainable bit-for-bit after
    one logical round (same rng)."""
    ds, task, tr0 = setup
    fleet = make_fleet(4, 0, 0, M=4)  # identical devices, full modalities
    kw = dict(rounds=1, local_epochs=1, steps_per_epoch=2, batch_size=8,
              eval_every=10, seed=0)
    sync = FedRun.create(task, tr0, get_strategy("relief"), fleet,
                         FedConfig(**kw))
    sync.round(ds)

    arun = AsyncFedRun.create(
        task, tr0, async_relief(buffer_size=fleet.N, staleness_exponent=0.0),
        fleet, AsyncFedConfig(**kw))
    arun.run(ds, total_updates=fleet.N)

    assert arun.state.round == 1  # exactly one flush
    for a, b in zip(jax.tree.leaves(sync.state.trainable),
                    jax.tree.leaves(arun.state.trainable)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_parity_streaming_pallas_interpret(setup):
    """Same parity flush through the Pallas (interpret) cohort-agg path —
    kernel and XLA oracle agree to float tolerance on the fused leaf."""
    ds, task, tr0 = setup
    fleet = make_fleet(4, 0, 0, M=4)
    kw = dict(rounds=1, local_epochs=1, steps_per_epoch=2, batch_size=8,
              eval_every=10, seed=0)
    runs = {}
    for impl in ("xla", "pallas"):
        r = AsyncFedRun.create(
            task, tr0,
            async_relief(buffer_size=fleet.N, staleness_exponent=0.0),
            fleet, AsyncFedConfig(agg_impl=impl, agg_interpret=True, **kw))
        r.run(ds, total_updates=fleet.N)
        runs[impl] = r.state.trainable
    for a, b in zip(jax.tree.leaves(runs["xla"]),
                    jax.tree.leaves(runs["pallas"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


# ---------------------------------------------------------------------------
# straggler decoupling at 100x heterogeneity
# ---------------------------------------------------------------------------


@pytest.mark.slow  # ~40s: two full federated runs on 1 CPU core
def test_async_beats_sync_wallclock_at_100x(setup):
    """At 100x compute heterogeneity the async runtime reaches the sync
    FedAvg run's target loss in less simulated wall-clock, and absorbs the
    same total client work in strictly less time (no straggler barrier)."""
    ds, task, tr0 = setup
    fleet = make_fleet(3, 3, 2, M=4, hetero_scale=100.0)
    R = 8
    kw = dict(rounds=R, local_epochs=2, steps_per_epoch=3, batch_size=32,
              eval_every=100, seed=0, utilization=1e-4, t_overhead=1e-3)
    sync = FedRun.create(task, tr0, get_strategy("fedavg"), fleet,
                         FedConfig(**kw))
    hs = sync.run(ds)
    sync_times = np.cumsum(hs["round_time_s"])
    sync_total = float(sync_times[-1])
    target = float(np.mean(hs["loss"][-2:]))

    arun = AsyncFedRun.create(
        task, tr0, async_relief(buffer_size=2, staleness_exponent=0.5),
        fleet, AsyncFedConfig(**kw))
    ha = arun.run(ds)  # same total client updates: R * N

    # same total work, strictly less simulated wall-clock
    assert arun.state.sim_time < sync_total
    # time-to-target-loss (running mean over 3 flushes vs sync final loss)
    smoothed = np.convolve(ha["loss"], np.ones(3) / 3.0, mode="valid")
    reached = np.where(smoothed <= target)[0]
    assert reached.size > 0, (target, smoothed.min())
    t_async = ha["sim_time_s"][int(reached[0]) + 2]
    # sync hits its target only at its final round
    assert t_async < sync_total
    # fast devices actually cycle more often than stragglers
    ups = arun.trace.per_client_updates
    assert ups[np.argmax(fleet.tops)] > ups[np.argmin(fleet.tops)]


# ---------------------------------------------------------------------------
# cohort safety under partial buffers
# ---------------------------------------------------------------------------


def test_empty_cohort_buffers_freeze_blocks(setup):
    """No buffered client owns modalities 2/3 -> their fusion blocks and
    encoder groups stay exactly frozen across flushes; nothing goes NaN."""
    ds, task, tr0 = setup
    fleet = make_fleet(0, 2, 2, M=4)  # mid: {0,1}, low: {0} — 2,3 absent
    fed = AsyncFedConfig(rounds=3, local_epochs=1, steps_per_epoch=2,
                         batch_size=8, eval_every=100, seed=0)
    arun = AsyncFedRun.create(task, tr0,
                              async_accessible(buffer_size=2,
                                               staleness_exponent=0.5),
                              fleet, fed)
    arun.run(ds)
    assert arun.state.round >= 3
    layout = task.layout
    frozen_groups = {g for g in range(layout.G)
                     if layout.modality[g] in (2, 3)}
    leaves0 = jax.tree_util.tree_flatten_with_path(tr0)[0]
    leaves1 = jax.tree_util.tree_flatten_with_path(arun.state.trainable)[0]
    rg = layout.row_group_vector(
        next(l for p, l in leaves0
             if mdlora.path_str(p) == layout.fusion_a_path).shape[0])
    for (p0, l0), (_, l1) in zip(leaves0, leaves1):
        a0, a1 = np.asarray(l0, np.float32), np.asarray(l1, np.float32)
        assert np.isfinite(a1).all(), mdlora.path_str(p0)
        p = mdlora.path_str(p0)
        if p == layout.fusion_a_path:
            frozen_rows = np.isin(rg, list(frozen_groups))
            np.testing.assert_array_equal(a0[frozen_rows], a1[frozen_rows])
        elif layout.leaf_group.get(p) in frozen_groups:
            np.testing.assert_array_equal(a0, a1)


def test_staleness_discount_downweights_stale_clients(setup):
    _, task, _ = setup
    layout = task.layout
    trained = jnp.ones((2, layout.G))
    mmask = jnp.ones((2, layout.n_modalities))
    disc = AG.staleness_discounts(np.array([0.0, 3.0]), 1.0)  # 1 and 1/4
    W = AG.cohort_weights(layout, trained, mmask, client_scale=disc)
    Wn = np.asarray(W)
    nz = layout.sizes > 0
    assert (Wn[0, nz] > Wn[1, nz]).all()
    np.testing.assert_allclose(Wn[:, nz].sum(0), 1.0, rtol=1e-6)
    # exponent 0 == no discounting
    W0 = AG.cohort_weights(layout, trained, mmask,
                           client_scale=AG.staleness_discounts(
                               np.array([0.0, 3.0]), 0.0))
    np.testing.assert_array_equal(np.asarray(W0)[:, nz], 0.5)


# ---------------------------------------------------------------------------
# streaming cohort-agg reduction
# ---------------------------------------------------------------------------


def test_streaming_chunks_match_one_shot(setup):
    """CohortAggBuffer over arbitrary chunkings == the one-shot
    weighted_combine + group_divergence reduction."""
    _, task, tr0 = setup
    layout = task.layout
    rng = np.random.default_rng(0)
    N = 6
    deltas = jax.tree.map(
        lambda x: jnp.asarray(rng.normal(size=(N,) + x.shape), jnp.float32),
        tr0)
    trained = jnp.asarray(rng.random((N, layout.G)) > 0.4, jnp.float32)
    mmask = jnp.asarray(rng.random((N, layout.n_modalities)) > 0.3,
                        jnp.float32)
    W = AG.cohort_weights(layout, trained, mmask)
    C = trained

    ref_agg = mdlora.weighted_combine(layout, deltas, W)
    ref_d = DV.group_divergence(layout, deltas, C)

    for chunks in ([slice(0, 6)], [slice(0, 2), slice(2, 5), slice(5, 6)]):
        buf = AG.CohortAggBuffer(layout, tr0)
        for sl in chunks:
            buf.push(jax.tree.map(lambda x: x[sl], deltas), W[sl], C[sl])
        agg, d, cnt = buf.finalize()
        for a, b in zip(jax.tree.leaves(ref_agg), jax.tree.leaves(agg)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
        np.testing.assert_allclose(np.asarray(d), np.asarray(ref_d),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(cnt),
                                      np.asarray(C.sum(0)))


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_push_quantized_matches_push_dequantized(setup, impl):
    """Fused int8 ingest through the buffer == dequantizing client-side and
    pushing fp32, with the staleness discount folded in (defer_scale)."""
    from repro import dist

    _, task, tr0 = setup
    layout = task.layout
    rng = np.random.default_rng(1)
    N = 5
    deltas = jax.tree.map(
        lambda x: jnp.asarray(rng.normal(size=(N,) + x.shape) * 1e-2,
                              jnp.float32), tr0)
    q, scales, _ = dist.quantize_int8_stacked(deltas)
    deq = dist.dequantize_int8_stacked(q, scales)
    trained = jnp.asarray(rng.random((N, layout.G)) > 0.3, jnp.float32)
    mmask = jnp.asarray(rng.random((N, layout.n_modalities)) > 0.2,
                        jnp.float32)
    staleness = jnp.asarray(rng.integers(0, 5, N), jnp.float32)
    a = 0.5
    disc = AG.staleness_discounts(staleness, a)
    W_full = AG.cohort_weights(layout, trained, mmask, client_scale=disc)
    W_def = AG.cohort_weights(layout, trained, mmask, client_scale=disc,
                              defer_scale=True)
    C = trained

    ref = AG.CohortAggBuffer(layout, tr0, impl=impl, interpret=True)
    ref.push(deq, W_full, C)
    ref_agg, ref_d, ref_cnt = ref.finalize()

    buf = AG.CohortAggBuffer(layout, tr0, impl=impl, interpret=True)
    buf.push_quantized(q, scales, W_def, C, staleness=staleness, exponent=a)
    agg, d, cnt = buf.finalize()

    for x, y in zip(jax.tree.leaves(ref_agg), jax.tree.leaves(agg)):
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)
    np.testing.assert_allclose(np.asarray(d), np.asarray(ref_d), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(ref_cnt))


def test_uplink_codec_int8_end_to_end(setup):
    """uplink_codec='int8' runs both runtimes with finite losses, ~4x less
    upload than fp32, heap/vectorized parity, and bounded model drift."""
    from repro.core.async_engine import VectorizedAsyncFedRun

    ds, task, tr0 = setup
    fleet = make_fleet(3, 3, 2, M=4)
    kw = dict(rounds=2, local_epochs=1, steps_per_epoch=2, batch_size=8,
              eval_every=100, seed=0)
    strat = lambda: async_relief(buffer_size=3, staleness_exponent=0.5)  # noqa: E731

    r32 = AsyncFedRun.create(task, tr0, strat(), fleet, AsyncFedConfig(**kw))
    h32 = r32.run(ds)
    r8 = AsyncFedRun.create(task, tr0, strat(), fleet,
                            AsyncFedConfig(uplink_codec="int8", **kw))
    h8 = r8.run(ds)
    assert np.isfinite(h8["loss"]).all()
    # int8 uplink: 1 byte/param instead of 4
    assert h8["upload_mb"][-1] < h32["upload_mb"][-1] / 3.5
    # quantization noise stays small relative to the model update
    for a, b in zip(jax.tree.leaves(r32.state.trainable),
                    jax.tree.leaves(r8.state.trainable)):
        np.testing.assert_allclose(np.asarray(b, np.float32),
                                   np.asarray(a, np.float32), atol=5e-2)

    rv = VectorizedAsyncFedRun.create(task, tr0, strat(), fleet,
                                      AsyncFedConfig(uplink_codec="int8",
                                                     **kw))
    hv = rv.run(ds)
    np.testing.assert_allclose(hv["loss"], h8["loss"], rtol=1e-5)
    for a, b in zip(jax.tree.leaves(r8.state.trainable),
                    jax.tree.leaves(rv.state.trainable)):
        np.testing.assert_allclose(np.asarray(b, np.float32),
                                   np.asarray(a, np.float32), atol=2e-5)


def test_uplink_codec_validated():
    from repro.core.async_engine import AsyncFedConfig as C

    with pytest.raises(ValueError, match="uplink_codec"):
        from repro.core.async_engine import AsyncFedRun as R
        cfg = mm_config_for("pamap2", backbone="cnn", d_feat=8, d_fused=32,
                            cnn_ch=(8, 16))
        task, tr0 = MMTask.create(cfg, KEY)
        R.create(task, tr0, async_relief(buffer_size=2),
                 make_fleet(2, 0, 0, M=4), C(rounds=1, uplink_codec="int4"))


def test_async_fedbuff_runs_and_improves(setup):
    """The modality-unaware async baseline runs end to end with finite
    losses and a valid F1."""
    ds, task, tr0 = setup
    fleet = make_fleet(3, 3, 2, M=4)
    fed = AsyncFedConfig(rounds=2, local_epochs=1, steps_per_epoch=2,
                         batch_size=8, eval_every=100, seed=0)
    arun = AsyncFedRun.create(task, tr0,
                              async_fedbuff(buffer_size=3,
                                            staleness_exponent=0.5),
                              fleet, fed)
    h = arun.run(ds)
    assert np.isfinite(h["loss"]).all()
    assert 0.0 <= h["f1"][-1] <= 1.0
    assert arun.trace.completions == 2 * fleet.N
    assert (np.diff(h["sim_time_s"]) >= 0).all()  # time moves forward
