"""Serving/training feature tests: int8 KV cache, chunked CE, unroll parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import api

KEY = jax.random.PRNGKey(0)


def _kv_dtypes(caches) -> set:
    """k/v leaf dtypes across both cache layouts (plain dict and the
    per-sublayer list used by alternating-window archs)."""
    subs = caches["__per_sub__"] if isinstance(caches, dict) and \
        "__per_sub__" in caches else [caches]
    return {c[name].dtype for c in subs for name in ("k", "v")}


@pytest.mark.parametrize("arch", [
    pytest.param("gemma2-27b", marks=pytest.mark.slow),  # >30s on 1 core
    "phi3-medium-14b",
])
def test_int8_kv_cache_decode_parity(arch):
    """int8 KV (per-token/head scales) must preserve greedy decode."""
    cfg = base.get_arch(arch).SMOKE
    cfgQ = dataclasses.replace(cfg, kv_quant=True)
    p = api.init_model(KEY, cfg)
    B, S = 2, 12
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    c1 = api.init_caches(cfg, B, S)
    c2 = api.init_caches(cfgQ, B, S)
    # the old form (`assert x == y if cond else True`) parsed as
    # `assert (x == y if cond else True)` and silently skipped the
    # per-sublayer layout; check every layout's k/v leaves explicitly
    assert _kv_dtypes(c2) == {np.dtype(np.int8)}
    for t in range(S):
        l1, c1 = api.decode_step(p, cfg, c1, tok[:, t:t + 1], jnp.int32(t))
        l2, c2 = api.decode_step(p, cfgQ, c2, tok[:, t:t + 1], jnp.int32(t))
    p1 = jax.nn.softmax(l1[:, 0])
    p2 = jax.nn.softmax(l2[:, 0])
    tv = float(0.5 * jnp.sum(jnp.abs(p1 - p2), -1).max())
    assert tv < 0.05
    assert bool(jnp.all(jnp.argmax(l1, -1) == jnp.argmax(l2, -1)))


def test_int8_cache_memory_halved():
    cfg = base.get_arch("phi3-medium-14b").SMOKE
    cfgQ = dataclasses.replace(cfg, kv_quant=True)
    c1 = api.init_caches(cfg, 2, 64)
    c2 = api.init_caches(cfgQ, 2, 64)
    b1 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c1))
    b2 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c2))
    assert b2 < 0.65 * b1  # int8 + small scale arrays


@pytest.mark.parametrize("arch,chunks", [("phi3-medium-14b", 4),
                                         ("musicgen-large", 4)])
def test_chunked_ce_matches_plain(arch, chunks):
    cfg = base.get_arch(arch).SMOKE
    cfgC = dataclasses.replace(cfg, loss_chunks=chunks)
    p = api.init_model(KEY, cfg)
    B, S = 2, 32
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    tok = jax.random.randint(jax.random.PRNGKey(1), shape, 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    l1 = api.loss_fn(p, cfg, batch)
    l2 = api.loss_fn(p, cfgC, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    # gradients agree too
    g1 = jax.grad(lambda q: api.loss_fn(q, cfg, batch))(p)
    g2 = jax.grad(lambda q: api.loss_fn(q, cfgC, batch))(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_seq_shard_flag_is_numerically_inert():
    """seq_shard only adds sharding constraints — without a registered mesh
    the outputs are identical."""
    cfg = base.get_arch("granite-3-8b").SMOKE
    cfgS = dataclasses.replace(cfg, seq_shard=True)
    p = api.init_model(KEY, cfg)
    tok = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    l1, _ = api.forward(p, cfg, {"tokens": tok})
    l2, _ = api.forward(p, cfgS, {"tokens": tok})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2))
