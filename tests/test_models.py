"""Per-architecture smoke tests + cross-path consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import api

KEY = jax.random.PRNGKey(0)
ARCHS = base.list_archs()


def make_batch(cfg, B=2, S=32):
    if cfg.n_codebooks:
        tok = jax.random.randint(KEY, (B, S, cfg.n_codebooks), 0, cfg.vocab)
        return {"tokens": tok, "labels": tok}
    tok = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(KEY, (B, cfg.n_patches,
                                                   cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_grad_decode(arch):
    cfg = base.get_arch(arch).SMOKE
    params = api.init_model(KEY, cfg)
    batch = make_batch(cfg)
    loss = api.loss_fn(params, cfg, batch)
    assert jnp.isfinite(loss)
    grads = jax.grad(lambda p: api.loss_fn(p, cfg, batch))(params)
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0
    caches = api.init_caches(cfg, 2, 16)
    tok = batch["tokens"][:, :1]
    logits, _ = api.decode_step(params, cfg, caches, tok, jnp.int32(0))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "gemma2-27b",
                                  "mixtral-8x7b", "mamba2-1.3b",
                                  "hymba-1.5b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the full-sequence forward."""
    cfg = base.get_arch(arch).SMOKE
    if cfg.n_experts:
        # capacity drops are a train-path semantic; decode (S=1) never
        # drops, so compare at a no-drop capacity factor
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = api.init_model(KEY, cfg)
    B, S = 2, 16
    tok = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    full_logits, _ = api.forward(params, cfg, {"tokens": tok})
    caches = api.init_caches(cfg, B, S)
    outs = []
    for t in range(S):
        lg, caches = api.decode_step(params, cfg, caches, tok[:, t:t + 1],
                                     jnp.int32(t))
        outs.append(lg[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """FULL configs carry the exact published hyperparameters."""
    expected = {
        "phi3-medium-14b": dict(n_layers=40, d_model=5120, n_heads=40,
                                n_kv_heads=10, d_ff=17920, vocab=100352),
        "gemma2-27b": dict(n_layers=46, d_model=4608, n_heads=32,
                           n_kv_heads=16, d_ff=36864, vocab=256000),
        "granite-34b": dict(n_layers=88, d_model=6144, n_heads=48,
                            n_kv_heads=1, d_ff=24576, vocab=49152),
        "granite-3-8b": dict(n_layers=40, d_model=4096, n_heads=32,
                             n_kv_heads=8, d_ff=12800, vocab=49155),
        "llava-next-34b": dict(n_layers=60, d_model=7168, n_heads=56,
                               n_kv_heads=8, d_ff=20480, vocab=64000),
        "musicgen-large": dict(n_layers=48, d_model=2048, n_heads=32,
                               n_kv_heads=32, d_ff=8192, vocab=2048,
                               n_codebooks=4),
        "mixtral-8x7b": dict(n_layers=32, d_model=4096, n_heads=32,
                             n_kv_heads=8, d_ff=14336, vocab=32000,
                             n_experts=8, top_k=2),
        "mixtral-8x22b": dict(n_layers=56, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab=32768,
                              n_experts=8, top_k=2),
        "mamba2-1.3b": dict(n_layers=48, d_model=2048, vocab=50280,
                            ssm_state=128),
        "hymba-1.5b": dict(n_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, vocab=32001,
                           ssm_state=16),
    }[arch]
    cfg = base.get_arch(arch).FULL
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_moe_capacity_drops_are_bounded():
    """Sort-based dispatch keeps >= (1 - small) of routed mass at cf=1.25."""
    cfg = base.get_arch("mixtral-8x7b").SMOKE
    params = api.init_model(KEY, cfg)
    from repro.models.moe import moe_mlp

    x = jax.random.normal(KEY, (4, 64, cfg.d_model))
    p0 = jax.tree.map(lambda a: a[0], params["base"]["layers"]["mlp"])
    out, aux = moe_mlp(p0, x, top_k=cfg.top_k, capacity_factor=1.25)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # load-balance loss lower bound is 1


def test_moe_matches_dense_reference():
    """Capacity-gather MoE == explicit per-token expert mixture (high cf)."""
    from repro.models import moe as MOE

    d, f, E, T = 16, 32, 4, 24
    p = MOE.init_moe_mlp(KEY, d, f, E)
    x = jax.random.normal(KEY, (1, T, d))
    out, _ = MOE.moe_mlp(p, x, top_k=2, capacity_factor=float(E))  # no drops
    logits = x.reshape(T, d) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gv, ei = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    want = np.zeros((T, d), np.float32)
    xf = np.asarray(x.reshape(T, d))
    for t in range(T):
        for j in range(2):
            e = int(ei[t, j])
            h = (np.asarray(jax.nn.silu(xf[t] @ p["wg"][e]))
                 * np.asarray(xf[t] @ p["wi"][e]))
            want[t] += float(gv[t, j]) * (h @ np.asarray(p["wo"][e]))
    np.testing.assert_allclose(np.asarray(out.reshape(T, d)), want,
                               atol=1e-4)


def test_vocab_padding_transparent():
    cfg = base.get_arch("granite-3-8b").SMOKE  # vocab 99 -> padded 128
    from repro.models.transformer import padded_vocab
    assert padded_vocab(cfg) == 128
    params = api.init_model(KEY, cfg)
    assert params["base"]["embed"].shape[0] == 128
    logits, _ = api.forward(params, cfg, {"tokens": jnp.zeros((1, 8),
                                                              jnp.int32)})
    assert logits.shape[-1] == cfg.vocab  # sliced back to the true vocab
