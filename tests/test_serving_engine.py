"""Continuous-batching multi-LoRA engine vs the per-request baseline.

The acceptance bar: the gathered batched decode must produce the *same
tokens* as merging each request's adapter into its own model and decoding
sequentially (greedy, float32 SMOKE) — including when requests join and
leave the batch mid-stream.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.launch.serving_engine import (AdapterRegistry, Request,
                                         ServingEngine, naive_serve)
from repro.models import api

KEY = jax.random.PRNGKey(0)


def _setup(arch, n_adapters, kv_quant=False, seed=0):
    cfg = base.get_arch(arch).SMOKE
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    params = api.init_model(KEY, cfg)
    rng = np.random.default_rng(seed)
    reg = AdapterRegistry(jax.random.PRNGKey(1), cfg, capacity=n_adapters)
    nb = len(reg.block_dims)
    for i in range(n_adapters):
        lora = api.init_model(jax.random.PRNGKey(50 + i), cfg)["lora"]
        # perturb b away from zero so adapters produce distinct outputs
        lora = jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(
                jax.random.PRNGKey(99 + i), x.shape, x.dtype), lora)
        mm = np.ones(nb, np.float32)
        if nb > 1:
            mm[int(rng.integers(1, nb))] = 0.0
        reg.register(f"c{i}", lora, modality_mask=mm)
    return cfg, params, reg, rng


def _requests(rng, cfg, n, n_adapters, plens, new_tokens):
    return [Request(rid=f"r{i}",
                    prompt=rng.integers(0, cfg.vocab, int(plens[i])),
                    adapter=f"c{i % n_adapters}",
                    max_new_tokens=int(new_tokens[i])) for i in range(n)]


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "hymba-1.5b"])
def test_batched_decode_matches_per_request_loop(arch):
    """Uniform lengths: whole batch decodes in lockstep; tokens identical."""
    cfg, params, reg, rng = _setup(arch, n_adapters=3)
    reqs = _requests(rng, cfg, 4, 3, plens=[6] * 4, new_tokens=[8] * 4)
    eng = ServingEngine(params, cfg, reg, batch_slots=4, max_len=20)
    for r in reqs:
        eng.submit(r)
    got = eng.run()["outputs"]
    ref = naive_serve(params, cfg, reg, reqs, max_len=20)["outputs"]
    assert got == ref


@pytest.mark.parametrize("arch", ["phi3-medium-14b", "hymba-1.5b"])
def test_join_leave_does_not_perturb_survivors(arch):
    """2 slots, 5 requests with ragged lengths: rows finish and new requests
    join mid-stream; every request still matches its solo reference."""
    cfg, params, reg, rng = _setup(arch, n_adapters=3)
    reqs = _requests(rng, cfg, 5, 3, plens=[4, 7, 5, 6, 3],
                     new_tokens=[6, 3, 8, 4, 7])
    eng = ServingEngine(params, cfg, reg, batch_slots=2, max_len=24)
    for r in reqs:
        eng.submit(r)
    got = eng.run()["outputs"]
    ref = naive_serve(params, cfg, reg, reqs, max_len=24)["outputs"]
    assert got == ref


def test_submission_order_permutation_invariance():
    """Reordering the queue must not change any request's tokens."""
    cfg, params, reg, rng = _setup("phi3-medium-14b", n_adapters=4)
    reqs = _requests(rng, cfg, 6, 4, plens=[5, 3, 6, 4, 7, 5],
                     new_tokens=[4, 6, 3, 5, 4, 6])
    outs = []
    for order in (range(6), [3, 0, 5, 1, 4, 2]):
        eng = ServingEngine(params, cfg, reg, batch_slots=3, max_len=20)
        for i in order:
            eng.submit(reqs[i])
        outs.append(eng.run()["outputs"])
    assert outs[0] == outs[1]


def test_engine_composes_with_int8_kv_cache():
    """Gathered batched decode over int8 KV caches == per-request int8."""
    cfg, params, reg, rng = _setup("phi3-medium-14b", n_adapters=2,
                                   kv_quant=True)
    reqs = _requests(rng, cfg, 3, 2, plens=[5, 4, 6], new_tokens=[6, 5, 4])
    eng = ServingEngine(params, cfg, reg, batch_slots=2, max_len=16)
    for r in reqs:
        eng.submit(r)
    got = eng.run()["outputs"]
    ref = naive_serve(params, cfg, reg, reqs, max_len=16)["outputs"]
    assert got == ref
    c = eng.caches
    leaves = c["__per_sub__"] if isinstance(c, dict) and "__per_sub__" in c \
        else [c]
    assert all(x["k"].dtype == jnp.int8 for x in leaves)


def test_registry_ingest_update_and_recycle():
    """ingest_update changes served outputs in place; evicted slots are
    reused and a recycled batch slot carries no state from its previous
    occupant (fresh prefill overwrites the row)."""
    cfg, params, reg, rng = _setup("phi3-medium-14b", n_adapters=2)
    prompt = rng.integers(0, cfg.vocab, 6)
    req = Request(rid="a", prompt=prompt, adapter="c0", max_new_tokens=6)

    def serve_one(adapter):
        eng = ServingEngine(params, cfg, reg, batch_slots=1, max_len=16)
        eng.submit(Request(rid="x", prompt=prompt, adapter=adapter,
                           max_new_tokens=6))
        return eng.run()["outputs"]["x"]

    before = serve_one("c0")
    # server round arrives: apply a large delta to c0's blocks
    delta = jax.tree.map(lambda x: jnp.ones_like(x[:, 0]) * 0.3, reg.store)
    reg.ingest_update("c0", delta, server_lr=1.0)
    after = serve_one("c0")
    assert before != after  # adapter update is visible without repacking
    ref = naive_serve(params, cfg, reg, [req], max_len=16)["outputs"]["a"]
    assert after == ref  # still exact vs merged per-request decode

    # evict + register a new client into the freed slot
    reg.evict("c1")
    s = reg.register("c2", api.init_model(jax.random.PRNGKey(7), cfg)["lora"])
    assert s == reg.slot("c2")
    # one engine, two sequential occupants of the same batch slot: second
    # run through the recycled slot must equal its solo reference
    eng = ServingEngine(params, cfg, reg, batch_slots=1, max_len=16)
    r1 = Request(rid="p", prompt=prompt, adapter="c0", max_new_tokens=4)
    r2 = Request(rid="q", prompt=rng.integers(0, cfg.vocab, 5),
                 adapter="c2", max_new_tokens=5)
    eng.submit(r1)
    eng.submit(r2)
    got = eng.run()["outputs"]
    ref = naive_serve(params, cfg, reg, [r1, r2], max_len=16)["outputs"]
    assert got == ref
