"""Integration tests for the federated engine (Algorithm 1) and strategies."""
import jax
import numpy as np
import pytest

from repro.core.engine import FedConfig, FedRun
from repro.core.strategies import (ABLATIONS, ALL_BASELINES, get_strategy)
from repro.core.tasks import MMTask
from repro.data import make_har_dataset, mm_config_for
from repro.sim import make_fleet

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    ds = make_har_dataset("pamap2", windows_per_subject=60, seed=0)
    fleet = make_fleet(3, 3, 2, M=4)
    cfg = mm_config_for("pamap2", backbone="cnn", d_feat=8, d_fused=32,
                        cnn_ch=(8, 16))
    task, tr0 = MMTask.create(cfg, KEY)
    fed = FedConfig(rounds=3, local_epochs=1, steps_per_epoch=2,
                    batch_size=16, eval_every=3, utilization=1e-4)
    return ds, fleet, task, tr0, fed


ALL_STRATEGIES = sorted(set(list(ALL_BASELINES) + list(ABLATIONS) +
                            ["relief"]))


@pytest.mark.parametrize("name", ALL_STRATEGIES)
def test_every_strategy_runs(setup, name):
    ds, fleet, task, tr0, fed = setup
    run = FedRun.create(task, tr0, get_strategy(name), fleet, fed)
    h = run.run(ds)
    assert len(h["round_time_s"]) == fed.rounds
    assert np.isfinite(h["loss"]).all()
    assert 0.0 <= h["f1"][-1] <= 1.0
    assert h["round_time_s"][-1] > 0
    assert h["upload_mb"][-1] >= 0


def test_loss_decreases_over_rounds(setup):
    ds, fleet, task, tr0, _ = setup
    fed = FedConfig(rounds=8, local_epochs=2, steps_per_epoch=3,
                    batch_size=32, eval_every=8)
    run = FedRun.create(task, tr0, get_strategy("relief"), fleet, fed)
    h = run.run(ds)
    assert np.mean(h["loss"][-3:]) < np.mean(h["loss"][:2])


def test_relief_faster_than_fedavg(setup):
    ds, fleet, task, tr0, fed = setup
    times = {}
    for name in ("relief", "fedavg"):
        run = FedRun.create(task, tr0, get_strategy(name), fleet, fed)
        h = run.run(ds)
        times[name] = np.mean(h["round_time_s"])
    assert times["relief"] < times["fedavg"]


def test_relief_uploads_less_than_fedavg(setup):
    ds, fleet, task, tr0, fed = setup
    mb = {}
    for name in ("relief", "fedavg"):
        run = FedRun.create(task, tr0, get_strategy(name), fleet, fed)
        h = run.run(ds)
        mb[name] = np.mean(h["upload_mb"])
    assert mb["relief"] < mb["fedavg"]


def test_client_dropout_fault_injection(setup):
    """Cohort-resilient aggregation: random client failures never crash a
    round and the model keeps training (fault tolerance)."""
    ds, fleet, task, tr0, _ = setup
    import dataclasses
    fed = FedConfig(rounds=5, local_epochs=1, steps_per_epoch=2,
                    batch_size=16, eval_every=5, dropout_prob=0.5, seed=3)
    run = FedRun.create(task, tr0, get_strategy("relief"), fleet, fed)
    h = run.run(ds)
    assert len(h["loss"]) == 5
    assert np.isfinite(h["loss"]).all()


def test_partial_participation(setup):
    ds, fleet, task, tr0, _ = setup
    fed = FedConfig(rounds=3, local_epochs=1, steps_per_epoch=2,
                    batch_size=16, eval_every=3, participation=0.5)
    run = FedRun.create(task, tr0, get_strategy("relief"), fleet, fed)
    h = run.run(ds)
    assert np.isfinite(h["loss"]).all()


def test_divergence_tracking_updates(setup):
    ds, fleet, task, tr0, fed = setup
    run = FedRun.create(task, tr0, get_strategy("relief"), fleet, fed)
    d0 = run.state.dbar.copy()
    run.round(ds)
    assert not np.allclose(run.state.dbar, d0)
    # only non-empty groups carry divergence
    assert (run.state.dbar[task.layout.sizes == 0] <= 1e-6).all()


def test_elastic_budgets_respect_mandatory(setup):
    ds, fleet, task, tr0, fed = setup
    from repro.core.engine import allocate
    run = FedRun.create(task, tr0, get_strategy("relief"), fleet, fed)
    S, k = allocate(run.strategy, run.state, task, fleet, fed,
                    task.layout.flops)
    man = task.layout.mandatory(fleet.modality_mask)
    assert (S >= man).all()
    assert (S.sum(1) <= np.maximum(k, man.sum(1))).all()
    acc = task.layout.accessible(fleet.modality_mask)
    assert (S <= acc).all()  # RELIEF never trains absent-modality groups


def test_fedavg_trains_absent_groups(setup):
    """The paper's Q2: classical FL wastes compute on absent-sensor params."""
    ds, fleet, task, tr0, fed = setup
    from repro.core.engine import allocate
    run = FedRun.create(task, tr0, get_strategy("fedavg"), fleet, fed)
    S, _ = allocate(run.strategy, run.state, task, fleet, fed,
                    task.layout.flops)
    acc = task.layout.accessible(fleet.modality_mask)
    assert (S & ~acc).any()  # trains groups it cannot benefit from


def test_harmony_keeps_fusion_local(setup):
    ds, fleet, task, tr0, fed = setup
    run = FedRun.create(task, tr0, get_strategy("harmony"), fleet, fed)
    run.round(ds)
    import jax.numpy as jnp
    # global fusion weight unchanged (not federated)
    leaves0 = jax.tree_util.tree_flatten_with_path(tr0)[0]
    leaves1 = jax.tree_util.tree_flatten_with_path(run.state.trainable)[0]
    for (p0, l0), (_, l1) in zip(leaves0, leaves1):
        pstr = jax.tree_util.keystr(p0)
        if "fusion" in pstr:
            np.testing.assert_allclose(np.asarray(l0), np.asarray(l1))


def test_helora_rank_masks(setup):
    ds, fleet, _, _, fed = setup
    cfg = mm_config_for("pamap2", backbone="transformer", d_feat=8,
                        d_fused=32, enc_layers=1, enc_d=16, enc_ff=32)
    task, tr0 = MMTask.create(cfg, KEY)
    run = FedRun.create(task, tr0, get_strategy("helora"), fleet, fed)
    h = run.run(ds)
    assert np.isfinite(h["loss"]).all()
    # slow clients have zeroed rank tails in their gates
    import jax.numpy as jnp
    ga = run.rank_gate["lora"]["fusion"]["a"]
    slow = int(np.argmin(fleet.tops))
    fast = int(np.argmax(fleet.tops))
    assert float(ga[slow].sum()) < float(ga[fast].sum())


def test_backbone2_runs(setup):
    ds, fleet, _, _, fed = setup
    cfg = mm_config_for("pamap2", backbone="transformer", d_feat=8,
                        d_fused=32, enc_layers=1, enc_d=16, enc_ff=32)
    task, tr0 = MMTask.create(cfg, KEY)
    run = FedRun.create(task, tr0, get_strategy("relief"), fleet, fed)
    h = run.run(ds)
    assert np.isfinite(h["loss"]).all()
    # B2 communicates the LoRA adapters + head only (<< full model)
    n_full = sum(x.size for x in jax.tree.leaves(task.params(tr0)))
    n_train = sum(x.size for x in jax.tree.leaves(tr0))
    assert n_train < 0.5 * n_full
