"""Byzantine-robust cohort reducer tests (core/aggregation.py): hypothesis
property tests for the array-level estimators (permutation invariance,
mean agreement, breakdown boundedness, degenerate-trim/median equivalence)
plus layout-level unit tests for robust_combine / Krum / the CohortAggBuffer
robust modes and their strategies.py wiring."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import dist
from repro.core import aggregation as AG
from repro.core import mdlora
from repro.core.async_engine import AsyncFedConfig, AsyncFedRun
from repro.core.strategies import (get_strategy, relief_krum, relief_median,
                                   relief_trimmed)
from repro.core.tasks import MMTask
from repro.data import make_har_dataset, mm_config_for

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def cnn_task():
    cfg = mm_config_for("pamap2", backbone="cnn", d_feat=8, d_fused=32,
                        cnn_ch=(8, 16))
    return MMTask.create(cfg, KEY)


def _stack(tree, n, key):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: jax.tree.map(
        lambda x: jax.random.normal(k, x.shape, jnp.float32), tree))(keys)


def _full_WC(lay, n):
    """Weights/cohort for n clients owning everything, training everything."""
    mm = jnp.ones((n, lay.n_modalities))
    trained = jnp.ones((n, lay.G)) * jnp.asarray(lay.sizes > 0)
    W = AG.cohort_weights(lay, trained, mm)
    C = trained
    return W, C


# ---------------------------------------------------------------------------
# property tests — array-level estimators
# ---------------------------------------------------------------------------

_vals = st.lists(st.floats(-100.0, 100.0, allow_nan=False, width=32),
                 min_size=3, max_size=9, unique=True)


@settings(max_examples=50, deadline=None)
@given(_vals, st.floats(0.0, 0.45), st.integers(0, 2**31 - 1))
def test_prop_permutation_invariance(vals, trim_frac, seed):
    """Shuffling the cohort rows never changes a robust estimate (values
    kept distinct: with exact duplicates and non-uniform weights the
    rank-based trim may keep a different duplicate, which is only
    value-equivalent under unique inputs)."""
    x = np.asarray(vals, np.float32)[:, None]
    w = (np.abs(x) * 0.1 + 0.5).astype(np.float32)  # positive, row-specific
    perm = np.random.default_rng(seed).permutation(len(vals))
    np.testing.assert_allclose(
        AG.trimmed_mean(x, w, trim_frac), AG.trimmed_mean(x[perm], w[perm],
                                                          trim_frac),
        rtol=1e-4, atol=1e-3)  # fp32 sums reassociate under permutation
    np.testing.assert_allclose(
        AG.coordinate_median(x, w > 0),
        AG.coordinate_median(x[perm], w[perm] > 0), rtol=1e-6)


@settings(max_examples=50, deadline=None)
@given(_vals)
def test_prop_trim_zero_is_weighted_mean(vals):
    """beta = 0 trims nothing: exactly the weighted mean sum(wx)/sum(w)."""
    x = np.asarray(vals, np.float32)[:, None]
    w = (np.abs(x) * 0.1 + 0.5).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(AG.trimmed_mean(x, w, 0.0))[0],
        float((w * x).sum() / w.sum()),
        rtol=1e-4, atol=1e-3)  # atol: near-cancelling sums in fp32


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-1.0, 1.0, allow_nan=False, width=32),
                min_size=4, max_size=8, unique=True),
       st.floats(-1e6, 1e6, allow_nan=False, width=32))
def test_prop_bounded_under_one_adversary(honest, evil):
    """Breakdown property: one adversarial row of arbitrary magnitude
    cannot push the trimmed mean (beta >= 1/k) or the median outside the
    honest values' range — while the plain mean follows the attacker."""
    x = np.asarray(honest + [evil], np.float32)[:, None]
    w = np.ones_like(x)
    lo, hi = min(honest), max(honest)
    t = float(AG.trimmed_mean(x, w, 0.25)[0])  # k>=5 => trims >=1 each side
    m = float(AG.coordinate_median(x, w > 0)[0])
    assert lo - 1e-5 <= t <= hi + 1e-5
    assert lo - 1e-5 <= m <= hi + 1e-5


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(-50.0, 50.0, allow_nan=False, width=32),
                min_size=3, max_size=9, unique=True))
def test_prop_degenerate_trim_equals_median(vals):
    """At beta -> 1/2 the trimmed mean degenerates to the median element
    (exactly so for odd cohorts, where a single middle value survives)."""
    if len(vals) % 2 == 0:
        vals = vals[:-1]
    x = np.asarray(vals, np.float32)[:, None]
    w = np.ones_like(x)
    np.testing.assert_allclose(AG.trimmed_mean(x, w, 0.5),
                               AG.coordinate_median(x, w > 0), rtol=1e-6)


# deterministic anchors for the same properties (run even without hypothesis)
def test_reducers_deterministic_anchor():
    x = np.array([[1.0], [3.0], [2.0], [1000.0]], np.float32)
    w = np.array([[0.1], [0.2], [0.3], [0.4]], np.float32)
    np.testing.assert_allclose(np.asarray(AG.trimmed_mean(x, w, 0.0))[0],
                               float((w * x).sum() / w.sum()), rtol=1e-5)
    assert 1.0 <= float(AG.trimmed_mean(x, w, 0.25)[0]) <= 3.0
    np.testing.assert_allclose(AG.coordinate_median(x, w > 0), [2.5])
    np.testing.assert_allclose(
        AG.trimmed_mean(x[:3], np.ones((3, 1), np.float32), 0.5),
        AG.coordinate_median(x[:3], np.ones((3, 1), bool)))
    # empty coordinate -> 0, never NaN
    assert float(AG.trimmed_mean(x, np.zeros_like(w), 0.1)[0]) == 0.0
    assert float(AG.coordinate_median(x, np.zeros_like(w, bool))[0]) == 0.0


# ---------------------------------------------------------------------------
# layout-level: robust_combine / Krum
# ---------------------------------------------------------------------------


def test_robust_mean_and_trim_zero_match_weighted_combine(cnn_task):
    """kind="mean" falls through to weighted_combine, and beta=0 trimming
    reproduces it exactly (cohort_weights columns sum to 1, so the trimmed
    mean's renormalization is a no-op)."""
    task, tr = cnn_task
    lay = task.layout
    deltas = _stack(tr, 5, KEY)
    W, _ = _full_WC(lay, 5)
    ref = mdlora.weighted_combine(lay, deltas, W)
    for kind, kw in (("mean", {}), ("trimmed", {"trim_frac": 0.0})):
        out = AG.robust_combine(lay, deltas, W, kind, **kw)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, err_msg=kind)


def test_krum_select_rejects_outlier(cnn_task):
    """Per modality block, Krum scores by distance to the k-f-2 nearest
    co-members: a lone large outlier is never the selected client."""
    task, tr = cnn_task
    lay = task.layout
    deltas = _stack(tr, 5, KEY)
    evil = 2
    deltas = jax.tree.map(
        lambda x: x.at[evil].set(x[evil] * 1e3 + 50.0), deltas)
    member = np.ones((5, lay.G), bool)
    d2 = AG.group_pairwise_sq(lay, deltas)
    sel = np.asarray(AG.krum_select(d2, jnp.asarray(member), f=1))
    nonempty = lay.sizes > 0
    assert (sel[nonempty] != evil).all()
    # and the Krum aggregate is one honest member's block — bounded
    agg = AG.robust_combine(lay, deltas, jnp.asarray(member, jnp.float32)
                            / 5.0, "krum", krum_f=1)
    honest_max = max(float(jnp.max(jnp.abs(jax.tree.leaves(deltas)[i])))
                    for i in range(len(jax.tree.leaves(deltas))))
    for leaf in jax.tree.leaves(agg):
        assert float(jnp.max(jnp.abs(leaf))) <= honest_max


def test_robust_combine_rejects_unknown_kind(cnn_task):
    task, tr = cnn_task
    deltas = _stack(tr, 3, KEY)
    with pytest.raises(ValueError, match="robust kind"):
        AG.robust_combine(task.layout, deltas, jnp.ones((3, task.layout.G)),
                          "huber")


# ---------------------------------------------------------------------------
# CohortAggBuffer robust modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("robust", ["trimmed", "median", "krum"])
def test_buffer_robust_bounded_where_mean_diverges(cnn_task, robust):
    """One corrupted client (x1000 blow-up) in a 5-client cohort: the plain
    mean follows the attacker; every robust mode stays within the honest
    aggregate's magnitude scale. Divergence stats are unchanged by design
    (they are always the plain Eq. 5 sufficient statistics)."""
    task, tr = cnn_task
    lay = task.layout
    deltas = _stack(tr, 5, KEY)
    corrupted = jax.tree.map(lambda x: x.at[0].mul(1000.0), deltas)
    W, C = _full_WC(lay, 5)

    def agg_norm(robust_kind, d):
        buf = AG.CohortAggBuffer(lay, tr, robust=robust_kind,
                                 trim_frac=0.25, krum_f=1)
        buf.push(d, W, C)
        agg, div, cnt = buf.finalize()
        return (np.sqrt(sum(float(jnp.sum(jnp.square(x)))
                            for x in jax.tree.leaves(agg))), np.asarray(div))

    honest_norm, _ = agg_norm("mean", deltas)
    mean_norm, div_mean = agg_norm("mean", corrupted)
    rob_norm, div_rob = agg_norm(robust, corrupted)
    assert mean_norm > 50 * honest_norm  # the mean diverged
    assert rob_norm < 5 * honest_norm  # the robust estimate did not
    np.testing.assert_allclose(div_rob, div_mean, rtol=1e-4)


def test_buffer_robust_requires_single_push(cnn_task):
    task, tr = cnn_task
    lay = task.layout
    deltas = _stack(tr, 4, KEY)
    W, C = _full_WC(lay, 4)
    buf = AG.CohortAggBuffer(lay, tr, robust="median")
    buf.push(deltas, W, C)
    with pytest.raises(RuntimeError, match="one push"):
        buf.push(deltas, W, C)
    buf.reset()
    buf.push(deltas, W, C)  # reset clears the guard
    with pytest.raises(ValueError, match="robust"):
        AG.CohortAggBuffer(lay, tr, robust="bogus")


def test_buffer_robust_quantized_dequantizes_first(cnn_task):
    """push_quantized under a robust mode falls back to dequantize + fp32
    push (order statistics cannot stream over int8 codes): the aggregate
    equals robust_combine over the dequantized stack with the staleness
    discount folded into the weights, and divergence matches the mean
    mode's quantized stats."""
    task, tr = cnn_task
    lay = task.layout
    deltas = _stack(tr, 5, KEY)
    q, scales, _ = dist.quantize_int8_stacked(deltas)
    deq = dist.dequantize_int8_stacked(q, scales)
    W, C = _full_WC(lay, 5)
    stale = jnp.asarray([0.0, 1.0, 2.0, 0.0, 3.0])
    a = 0.5

    buf = AG.CohortAggBuffer(lay, tr, robust="median")
    buf.push_quantized(q, scales, W, C, stale, a)
    agg, div, cnt = buf.finalize()

    disc = 1.0 / (1.0 + np.asarray(stale)) ** a
    ref = AG.robust_combine(lay, deq, W * jnp.asarray(disc)[:, None],
                            "median")
    for x, y in zip(jax.tree.leaves(agg), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)

    bufm = AG.CohortAggBuffer(lay, tr)
    bufm.push_quantized(q, scales, W, C, stale, a)
    _, div_mean, _ = bufm.finalize()
    np.testing.assert_allclose(np.asarray(div), np.asarray(div_mean),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# strategies wiring + end-to-end smoke
# ---------------------------------------------------------------------------


def test_strategy_registry_exposes_robust_entries():
    for name, kind in (("relief_trimmed", "trimmed"),
                       ("relief_median", "median"),
                       ("relief_krum", "krum")):
        s = get_strategy(name)
        assert s.robust == kind and s.agg == "cohort"
    assert get_strategy("async_relief").robust == "mean"
    assert relief_trimmed(trim_frac=0.3).trim_frac == 0.3
    assert relief_krum(krum_f=2).krum_f == 2
    assert relief_median().name == "relief_median"


def test_robust_strategy_end_to_end(cnn_task):
    """relief_median survives a sign-flip attack on a small fleet: the run
    completes, the aggregate stays finite, and the buffer was built in
    median mode."""
    from repro.sim import FaultModel, make_fleet, scale_fleet
    ds = make_har_dataset("pamap2", windows_per_subject=40, seed=0)
    task, tr0 = cnn_task
    fleet = scale_fleet(make_fleet(2, 2, 1, M=4), 24,
                        np.random.default_rng(5))
    fm = FaultModel(seed=1, byzantine_frac=0.3, corruption="sign_flip",
                    corruption_scale=50.0)
    run = AsyncFedRun.create(
        task, tr0, relief_median(buffer_size=8),
        fleet, AsyncFedConfig(rounds=1, local_epochs=1, steps_per_epoch=1,
                              batch_size=4, eval_every=0, seed=0, faults=fm))
    assert run.aggbuf.robust == "median"
    hist = run.run(ds, total_updates=40)
    assert np.isfinite(hist["loss"]).all()
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(run.state.trainable))


def test_check_strategy_rejects_bad_robust(cnn_task):
    task, tr0 = cnn_task
    from repro.sim import make_fleet
    s = dataclasses.replace(relief_median(), robust="bogus")
    with pytest.raises(ValueError, match="robust"):
        AsyncFedRun.create(task, tr0, s, make_fleet(2, 1, 1, M=4),
                           AsyncFedConfig())
