"""Pallas kernels vs. pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = np.random.default_rng(42)


def randn(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(KEY.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------------------
# mdlora
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("T,D,F,r", [(64, 64, 128, 4), (128, 256, 64, 8),
                                     (256, 128, 128, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mdlora_kernel_sweep(T, D, F, r, dtype):
    from repro.kernels.mdlora.ops import block_row_mask, mdlora_matmul

    x = randn((T, D), dtype)
    w0 = randn((D, F), dtype, 0.05)
    a = randn((D, r), dtype, 0.1)
    b = randn((r, F), dtype, 0.1)
    mask = block_row_mask([D // 2, D // 4, D // 4], [1.0, 0.0, 1.0])
    ref = mdlora_matmul(x, w0, a, b, mask, impl="xla")
    got = mdlora_matmul(x, w0, a, b, mask, impl="pallas", interpret=True,
                        bt=64, bf=64, bd=64)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_mdlora_masked_blocks_are_inert():
    """Absent-modality rows must not influence the output at all."""
    from repro.kernels.mdlora.ops import block_row_mask, mdlora_matmul

    T, D, F, r = 64, 128, 64, 8
    x = randn((T, D))
    w0, a, b = randn((D, F), scale=0.1), randn((D, r)), randn((r, F))
    mask = block_row_mask([64, 64], [1.0, 0.0])
    y1 = mdlora_matmul(x, w0, a, b, mask, impl="pallas", interpret=True,
                       bt=64, bf=64, bd=64)
    x2 = x.at[:, 64:].add(randn((T, 64), scale=100.0))  # poison masked rows
    y2 = mdlora_matmul(x2, w0, a, b, mask, impl="pallas", interpret=True,
                       bt=64, bf=64, bd=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


@pytest.mark.skipif(jax.default_backend() == "cpu",
                    reason="needs a compiled pallas backend (TPU/GPU)")
def test_mdlora_lowers_compiled():
    """Smoke: the mdlora kernel compiles non-interpreted off-CPU."""
    from repro.kernels.mdlora.ops import block_row_mask, mdlora_matmul

    T, D, F, r = 128, 128, 128, 8
    x = randn((T, D))
    w0, a, b = randn((D, F), scale=0.05), randn((D, r)), randn((r, F))
    mask = block_row_mask([D // 2, D // 2], [1.0, 0.0])
    out = mdlora_matmul(x, w0, a, b, mask, impl="pallas", interpret=False,
                        bt=64, bf=64, bd=64)
    assert np.isfinite(np.asarray(out)).all()


@pytest.mark.parametrize("B,D,F,r,A", [(8, 64, 128, 4, 3), (16, 128, 64, 8, 16),
                                       (4, 256, 128, 16, 2)])
def test_mdlora_multi_gathered_matches_per_row_loop(B, D, F, r, A):
    """One gathered call == B single-adapter calls with each row's adapter."""
    from repro.kernels.mdlora.ops import (block_row_masks, mdlora_matmul,
                                          mdlora_matmul_multi)

    x = randn((B, D))
    w0 = randn((D, F), scale=0.05)
    a = randn((A, D, r), scale=0.1)
    b = randn((A, r, F), scale=0.1)
    idx = jnp.asarray(KEY.integers(0, A, B), jnp.int32)
    masks = block_row_masks([D // 2, D // 2],
                            (KEY.random((B, 2)) < 0.7).astype(np.float32))
    for impl in ("xla", "pallas"):
        got = mdlora_matmul_multi(x, w0, a, b, idx, row_mask=masks,
                                  impl=impl, interpret=True)
        rows = [mdlora_matmul(x[i:i + 1], w0, a[int(idx[i])], b[int(idx[i])],
                              masks[i], impl="xla") for i in range(B)]
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(jnp.concatenate(rows)),
                                   atol=1e-5, rtol=1e-5)


def test_mdlora_multi_permutation_invariance():
    """Row order must not change any row's result (continuous batching
    shuffles which slot a request occupies)."""
    from repro.kernels.mdlora.ops import mdlora_matmul_multi

    B, D, F, r, A = 16, 128, 128, 8, 5
    x = randn((B, D))
    w0, a = randn((D, F), scale=0.05), randn((A, D, r), scale=0.1)
    b = randn((A, r, F), scale=0.1)
    idx = jnp.asarray(KEY.integers(0, A, B), jnp.int32)
    mask = jnp.asarray(KEY.random((B, D)) < 0.8, jnp.float32)
    perm = jnp.asarray(KEY.permutation(B), jnp.int32)
    y = mdlora_matmul_multi(x, w0, a, b, idx, row_mask=mask,
                            impl="pallas", interpret=True)
    yp = mdlora_matmul_multi(x[perm], w0, a, b, idx[perm],
                             row_mask=mask[perm], impl="pallas",
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(y)[np.asarray(perm)],
                                  np.asarray(yp))


def test_mdlora_multi_matches_single_when_uniform():
    """All rows on one adapter == the single-adapter kernel."""
    from repro.kernels.mdlora.ops import mdlora_matmul, mdlora_matmul_multi

    B, D, F, r = 32, 64, 64, 4
    x = randn((B, D))
    w0, a = randn((D, F), scale=0.05), randn((1, D, r), scale=0.1)
    b = randn((1, r, F), scale=0.1)
    mask = jnp.ones((D,), jnp.float32)
    y1 = mdlora_matmul(x, w0, a[0], b[0], mask, impl="xla")
    y2 = mdlora_matmul_multi(x, w0, a, b, jnp.zeros(B, jnp.int32),
                             impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


@pytest.mark.skipif(jax.default_backend() == "cpu",
                    reason="needs a compiled pallas backend (TPU/GPU)")
def test_mdlora_multi_lowers_compiled():
    from repro.kernels.mdlora.ops import mdlora_matmul_multi

    B, D, F, r, A = 16, 128, 128, 8, 4
    x = randn((B, D))
    w0, a = randn((D, F), scale=0.05), randn((A, D, r), scale=0.1)
    b = randn((A, r, F), scale=0.1)
    idx = jnp.asarray(KEY.integers(0, A, B), jnp.int32)
    out = mdlora_matmul_multi(x, w0, a, b, idx, impl="pallas",
                              interpret=False)
    assert np.isfinite(np.asarray(out)).all()


def test_mdlora_autotune_blocks_and_roofline_plan():
    """Autotuner returns VMEM-feasible divisors; roofline plan is coherent."""
    from repro.kernels.cohort_agg.autotune import (clear_cache,
                                                   mdlora_candidates,
                                                   select_mdlora_blocks)
    from repro.launch.roofline import mdlora_block_plan

    clear_cache()
    try:
        bt, bf, bd = select_mdlora_blocks((16, 192, 384, 8), multi=True,
                                          n_adapters=4)
        assert bt == 1 and 384 % bf == 0 and 192 % bd == 0
        for cell in mdlora_candidates(48, 192, 384, 8, multi=False):
            assert 48 % cell[0] == 0 and 384 % cell[1] == 0 \
                and 192 % cell[2] == 0
        plan = mdlora_block_plan([
            {"T": 16, "D": 192, "F": 384, "r": 8, "multi": True,
             "n_adapters": 4},
            {"T": 64, "D": 128, "F": 128, "r": 4}])
        assert len(plan) == 2
        for row in plan:
            assert row["flops"] > 0 and row["bytes"] > 0
            assert row["dominant"] in ("compute", "memory")
            assert row["F"] % row["bf"] == 0 and row["D"] % row["bd"] == 0
        assert plan[0]["bt"] == 1 and plan[0]["multi"]
    finally:
        clear_cache()


# ---------------------------------------------------------------------------
# cohort_agg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("N,D,r", [(4, 64, 4), (9, 128, 8), (16, 256, 1)])
def test_cohort_agg_kernel_sweep(N, D, r):
    from repro.kernels.cohort_agg.ops import cohort_agg_divergence

    deltas = randn((N, D, r))
    W = jnp.asarray(KEY.random((N, D)) * (KEY.random((N, D)) < 0.7),
                    jnp.float32)
    C = jnp.asarray(KEY.random((N, D)) < 0.6, jnp.float32)
    ref = cohort_agg_divergence(deltas, W, C, impl="xla")
    got = cohort_agg_divergence(deltas, W, C, impl="pallas", interpret=True,
                                bd=64)
    for a, b in zip(ref, got):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=1e-4)


def test_cohort_agg_divergence_reduction_matches_eq5():
    """Kernel stats -> Eq. 5 divergence == direct computation."""
    from repro.kernels.cohort_agg.ops import cohort_agg_divergence
    from repro.kernels.cohort_agg.ref import divergence_from_stats

    N, D, r = 6, 32, 4
    deltas = randn((N, D, r))
    C = jnp.asarray(KEY.random((N,)) < 0.8, jnp.float32)
    Cd = jnp.tile(C[:, None], (1, D))
    _, sq, mean, cnt = cohort_agg_divergence(deltas, Cd, Cd, impl="pallas",
                                             interpret=True, bd=32)
    rows = jnp.zeros(D, jnp.int32).at[D // 2:].set(1)  # two blocks
    d = divergence_from_stats(sq, mean, cnt, rows, 2)
    # direct Eq. 5 per block
    nC = float(C.sum())
    for blk, sl in enumerate([slice(0, D // 2), slice(D // 2, D)]):
        x = np.asarray(deltas[:, sl, :], np.float64)
        c = np.asarray(C, bool)
        mu = x[c].mean(0)
        want = float(np.mean([np.sum((x[i] - mu) ** 2)
                              for i in range(N) if c[i]]))
        np.testing.assert_allclose(float(d[blk]), want, rtol=1e-4)


def _quant_inputs(N, D, r):
    q = jnp.asarray(KEY.integers(-127, 128, (N, D, r)), jnp.int8)
    scales = jnp.asarray(KEY.uniform(1e-3, 1e-1, N), jnp.float32)
    W = jnp.asarray(KEY.random((N, D)) * (KEY.random((N, D)) < 0.7),
                    jnp.float32)
    C = jnp.asarray(KEY.random((N, D)) < 0.6, jnp.float32)
    staleness = jnp.asarray(KEY.integers(0, 6, N), jnp.float32)
    return q, scales, W, C, staleness


def _unfused_oracle(q, scales, W, C, staleness, exponent):
    """Materialize the fp32 stack, discount the weights, aggregate."""
    from repro.kernels.cohort_agg.ops import cohort_agg_divergence

    deltas = q.astype(jnp.float32) * scales[:, None, None]
    W_eff = W * jnp.power(1.0 + staleness, -exponent)[:, None]
    return cohort_agg_divergence(deltas, W_eff, C, impl="xla")


@pytest.mark.parametrize("N,D,r", [(4, 64, 4), (9, 96, 8), (16, 100, 1)])
@pytest.mark.parametrize("exponent", [0.0, 0.5])
def test_cohort_agg_quant_matches_unfused(N, D, r, exponent):
    """Fused int8 ingest == dequantize -> discount -> aggregate, for both
    impls, including non-divisible D (96, 100 vs default block caps)."""
    from repro.kernels.cohort_agg.ops import cohort_agg_divergence_quant

    q, scales, W, C, staleness = _quant_inputs(N, D, r)
    want = _unfused_oracle(q, scales, W, C, staleness, exponent)
    for impl in ("xla", "pallas"):
        got = cohort_agg_divergence_quant(q, scales, W, C, staleness,
                                          exponent=exponent, impl=impl,
                                          interpret=True)
        for a, b in zip(want, got):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=1e-4, atol=1e-4)


def test_cohort_agg_quant_empty_cohort():
    """All-zero W and C (nobody trained / nobody in cohort) stays finite."""
    from repro.kernels.cohort_agg.ops import cohort_agg_divergence_quant

    N, D, r = 5, 64, 4
    q, scales, _, _, staleness = _quant_inputs(N, D, r)
    Z = jnp.zeros((N, D), jnp.float32)
    for impl in ("xla", "pallas"):
        agg, sq, mean, cnt = cohort_agg_divergence_quant(
            q, scales, Z, Z, staleness, exponent=0.5, impl=impl,
            interpret=True)
        for x in (agg, sq, mean, cnt):
            assert np.isfinite(np.asarray(x)).all()
        np.testing.assert_array_equal(np.asarray(agg), 0.0)
        np.testing.assert_array_equal(np.asarray(cnt), 0.0)


def test_cohort_agg_explicit_bd_snaps_to_divisor():
    """bd larger than (or not dividing) D must snap, not silently misindex."""
    from repro.kernels.cohort_agg.ops import cohort_agg_divergence

    N, D, r = 6, 96, 4
    deltas = randn((N, D, r))
    W = jnp.asarray(KEY.random((N, D)), jnp.float32)
    C = jnp.asarray(KEY.random((N, D)) < 0.5, jnp.float32)
    ref = cohort_agg_divergence(deltas, W, C, impl="xla")
    for bd in (256, 64, 7):  # snap to 96, 48, 6
        got = cohort_agg_divergence(deltas, W, C, impl="pallas",
                                    interpret=True, bd=bd)
        for a, b in zip(ref, got):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       atol=1e-4)


def test_cohort_agg_autotune_candidates():
    from repro.kernels.cohort_agg import autotune

    assert autotune.largest_divisor(96, 64) == 48
    assert autotune.largest_divisor(100, 256) == 100
    assert autotune.largest_divisor(97, 64) == 1  # prime > cap
    for D in (96, 100, 256, 4096):
        cands = autotune.candidate_bds(D, r=4)
        assert cands and all(D % bd == 0 for bd in cands)
    bd = autotune.select_block_size((8, 256, 4), impl="pallas",
                                    interpret=True, quant=False)
    assert 256 % bd == 0
    # second call hits the process-level cache (same key -> same choice)
    assert autotune.select_block_size((8, 256, 4), impl="pallas",
                                      interpret=True, quant=False) == bd


def test_cohort_agg_default_interpret_tracks_backend():
    """interpret=None must resolve to interpret-mode only on CPU, so
    impl='pallas' is safe by default everywhere."""
    from repro.kernels.runtime import default_interpret, resolve_interpret

    on_cpu = jax.default_backend() == "cpu"
    assert default_interpret() is on_cpu
    assert resolve_interpret(None) is on_cpu
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


@pytest.mark.skipif(jax.default_backend() == "cpu",
                    reason="needs a compiled pallas backend (TPU/GPU)")
def test_cohort_agg_quant_lowers_compiled():
    """Smoke: the quant kernel compiles non-interpreted off-CPU."""
    from repro.kernels.cohort_agg.ops import cohort_agg_divergence_quant

    q, scales, W, C, staleness = _quant_inputs(8, 256, 4)
    out = cohort_agg_divergence_quant(q, scales, W, C, staleness,
                                      exponent=0.5, impl="pallas",
                                      interpret=None)
    assert np.isfinite(np.asarray(out[0])).all()


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,K,G,hd,window,softcap", [
    (64, 2, 2, 16, None, None),
    (128, 1, 4, 32, 32, None),
    (128, 4, 1, 64, None, 50.0),
    (64, 2, 3, 16, 16, 30.0),
])
def test_flash_attention_sweep(S, K, G, hd, window, softcap):
    from repro.kernels.flash_attention.ops import flash_attention

    B = 2
    q = randn((B, S, K, G, hd))
    k = randn((B, S, K, hd))
    v = randn((B, S, K, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    ref = flash_attention(q, k, v, pos, pos, window, softcap, impl="xla")
    got = flash_attention(q, k, v, pos, pos, window, softcap, impl="pallas",
                          interpret=True, bq=32, bt=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_attention_decode_ring_cache():
    from repro.kernels.flash_attention.ops import flash_attention

    B, T, K, G, hd = 2, 64, 2, 2, 16
    q = randn((B, 1, K, G, hd))
    k = randn((B, T, K, hd))
    v = randn((B, T, K, hd))
    kvpos = jnp.where(jnp.arange(T) < 50, jnp.arange(T), -1).astype(jnp.int32)
    qpos = jnp.array([49], jnp.int32)
    ref = flash_attention(q, k, v, qpos, kvpos, None, None, impl="xla")
    got = flash_attention(q, k, v, qpos, kvpos, None, None, impl="pallas",
                          interpret=True, bq=1, bt=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention.ops import flash_attention

    B, S, K, G, hd = 1, 64, 2, 2, 32
    q = randn((B, S, K, G, hd), jnp.bfloat16)
    k = randn((B, S, K, hd), jnp.bfloat16)
    v = randn((B, S, K, hd), jnp.bfloat16)
    pos = jnp.arange(S, dtype=jnp.int32)
    ref = flash_attention(q, k, v, pos, pos, None, None, impl="xla")
    got = flash_attention(q, k, v, pos, pos, None, None, impl="pallas",
                          interpret=True, bq=32, bt=32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


@pytest.mark.skipif(jax.default_backend() == "cpu",
                    reason="needs a compiled pallas backend (TPU/GPU)")
def test_flash_attention_lowers_compiled():
    """Smoke: flash attention compiles non-interpreted off-CPU."""
    from repro.kernels.flash_attention.ops import flash_attention

    B, S, K, G, hd = 2, 128, 2, 2, 32
    q = randn((B, S, K, G, hd))
    k = randn((B, S, K, hd))
    v = randn((B, S, K, hd))
    pos = jnp.arange(S, dtype=jnp.int32)
    out = flash_attention(q, k, v, pos, pos, None, None, impl="pallas",
                          interpret=False, bq=32, bt=32)
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,h,p,n,chunk,bh", [
    (64, 4, 16, 8, 16, 2), (128, 8, 8, 16, 32, 8), (32, 2, 32, 4, 32, 1),
])
def test_ssd_kernel_sweep(s, h, p, n, chunk, bh):
    from repro.kernels.ssd.ops import ssd

    b = 2
    x = randn((b, s, h, p))
    dt = jax.nn.softplus(randn((b, s, h)))
    A_log = randn((h,))
    Bm = randn((b, s, n))
    Cm = randn((b, s, n))
    yr, fr = ssd(x, dt, A_log, Bm, Cm, chunk=chunk, impl="xla")
    yp, fp = ssd(x, dt, A_log, Bm, Cm, chunk=chunk, impl="pallas",
                 interpret=True, bh=bh)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(fp), np.asarray(fr), atol=1e-4)


def test_ssd_kernel_matches_sequential_recurrence():
    """End-to-end: kernel == token-by-token recurrent decode."""
    from repro.kernels.ssd.ops import ssd
    from repro.models.ssm import ssd_decode_step

    b, s, h, p, n = 1, 32, 2, 8, 4
    x = randn((b, s, h, p))
    dt = jax.nn.softplus(randn((b, s, h)))
    A_log = randn((h,))
    Bm = randn((b, s, n))
    Cm = randn((b, s, n))
    y, fs = ssd(x, dt, A_log, Bm, Cm, chunk=8, impl="pallas", interpret=True,
                bh=2)
    state = jnp.zeros((b, h, p, n))
    for t in range(s):
        yt, state = ssd_decode_step(state, x[:, t], dt[:, t], A_log,
                                    Bm[:, t], Cm[:, t])
        np.testing.assert_allclose(np.asarray(y[:, t]), np.asarray(yt),
                                   atol=1e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(state), atol=1e-4)


@pytest.mark.skipif(jax.default_backend() == "cpu",
                    reason="needs a compiled pallas backend (TPU/GPU)")
def test_ssd_lowers_compiled():
    """Smoke: the ssd scan kernel compiles non-interpreted off-CPU."""
    from repro.kernels.ssd.ops import ssd

    b, s, h, p, n = 2, 128, 8, 16, 8
    x = randn((b, s, h, p))
    dt = jax.nn.softplus(randn((b, s, h)))
    A_log = randn((h,))
    Bm = randn((b, s, n))
    Cm = randn((b, s, n))
    y, fs = ssd(x, dt, A_log, Bm, Cm, chunk=32, impl="pallas",
                interpret=False, bh=8)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(fs)).all()
