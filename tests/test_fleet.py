"""Vectorized fleet-simulator tests (sim/fleet.py + VectorizedAsyncFedRun):
SoA primitives, heap/array history equivalence, determinism at 10^4 clients,
and population churn."""
import jax
import numpy as np
import pytest

from repro.core.async_engine import (AsyncFedConfig, AsyncFedRun,
                                     VectorizedAsyncFedRun)
from repro.core.strategies import async_fedbuff, async_relief
from repro.core.tasks import MMTask
from repro.data import make_har_dataset, mm_config_for
from repro.sim import FaultModel, make_fleet, scale_fleet
from repro.sim.fleet import (FleetState, PopulationModel, pack_group_bits,
                             unpack_group_bits)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    ds = make_har_dataset("pamap2", windows_per_subject=60, seed=0)
    cfg = mm_config_for("pamap2", backbone="cnn", d_feat=8, d_fused=32,
                        cnn_ch=(8, 16))
    task, tr0 = MMTask.create(cfg, KEY)
    return ds, task, tr0


# ---------------------------------------------------------------------------
# SoA primitives
# ---------------------------------------------------------------------------


def test_group_bits_roundtrip():
    rng = np.random.default_rng(0)
    S = rng.random((17, 23)) > 0.5
    np.testing.assert_array_equal(unpack_group_bits(pack_group_bits(S), 23),
                                  S)
    with pytest.raises(ValueError):
        pack_group_bits(np.ones((1, 65), bool))


def test_fleet_subset_slices_all_arrays():
    fleet = make_fleet(2, 2, 1, M=4)
    idx = np.array([4, 0, 2])
    sub = fleet.subset(idx)
    assert sub.N == 3
    np.testing.assert_array_equal(sub.tops, fleet.tops[idx])
    np.testing.assert_array_equal(sub.modality_mask,
                                  fleet.modality_mask[idx])
    np.testing.assert_array_equal(sub.active_power, fleet.active_power[idx])
    np.testing.assert_array_equal(sub.bandwidth_mbps,
                                  fleet.bandwidth_mbps[idx])
    assert sub.type_names == ["low", "full", "mid"]


def _dispatch_at(fs, idx, times, now=0.0):
    b = len(idx)
    fs.dispatch(np.asarray(idx), now, 0, np.zeros(b, np.uint64),
                np.asarray(times, np.float64) - now, np.zeros(b),
                np.zeros(b), np.zeros(b))


def test_peek_window_fifo_ties_never_split():
    """Equal completion times pop in dispatch order, and a tie group
    crossing the k-th-smallest boundary is included whole."""
    fs = FleetState.create(6)
    _dispatch_at(fs, [3, 1, 4], [2.0, 2.0, 2.0])
    _dispatch_at(fs, [0, 5], [1.0, 5.0])
    # k=2: the kth-smallest is 2.0 — the whole 2.0 tie group must come along
    times, idx = fs.peek_window(k=2, gap=np.inf)
    np.testing.assert_array_equal(times, [1.0, 2.0, 2.0, 2.0])
    np.testing.assert_array_equal(idx, [0, 3, 1, 4])  # FIFO within the tie


def test_peek_window_gap_truncation():
    """Only events strictly inside [t0, t0 + gap) are extractable in one
    batch (a redispatch of the first event cannot complete before t0+gap);
    gap=0 degenerates to exact pop_simultaneous semantics."""
    fs = FleetState.create(4)
    _dispatch_at(fs, [0, 1, 2, 3], [1.0, 1.02, 1.05, 1.2])
    times, idx = fs.peek_window(k=4, gap=0.05)
    np.testing.assert_array_equal(idx, [0, 1])  # 1.05 == t0+gap excluded
    times, idx = fs.peek_window(k=4, gap=0.0)
    np.testing.assert_array_equal(idx, [0])
    fs.claim(np.array([0, 1]))
    assert fs.in_flight == 2
    times, idx = fs.peek_window(k=4, gap=0.05)
    np.testing.assert_array_equal(idx, [2])


def test_depart_cancels_pending_even_after_rearrival():
    """A departure cancels the client's pending completion whether it is
    still scheduled or already claimed; re-arrival does not resurrect it —
    only the next dispatch clears the ``lost`` mark."""
    fs = FleetState.create(4)
    _dispatch_at(fs, [0, 1, 2, 3], [1.0, 2.0, 3.0, 4.0])
    fs.claim(np.array([0, 1]))  # window extracted, not yet absorbed
    fs.depart(np.array([1, 2]))  # 1 = claimed event, 2 = scheduled event
    assert fs.in_flight == 1  # only 3 remains scheduled (0 is claimed)
    assert fs.lost[[1, 2]].all() and not fs.lost[[0, 3]].any()
    fs.arrive(np.array([1, 2]))
    assert fs.alive[[1, 2]].all()
    assert fs.lost[[1, 2]].all()  # cancelled completions stay cancelled
    _dispatch_at(fs, [1], [5.0], now=4.0)
    assert not fs.lost[1] and fs.lost[2]
    assert fs.in_flight == 2


def test_population_step_departs_and_arrives():
    fs = FleetState.create(100)
    _dispatch_at(fs, np.arange(100), np.full(100, 1.0))
    pop = PopulationModel(churn_rate=50.0, arrival_rate=0.0)
    rng = np.random.default_rng(0)
    departed, _ = pop.step(rng, fs, dt=0.1)
    assert 0 < len(departed) < 100
    assert not fs.alive[departed].any()
    assert fs.in_flight == 100 - len(departed)  # in-flight work is lost
    arrived_pop = PopulationModel(churn_rate=0.0, arrival_rate=1e9)
    _, arrived = arrived_pop.step(rng, fs, dt=1.0)
    np.testing.assert_array_equal(np.sort(arrived), np.sort(departed))
    assert fs.alive.all()


# ---------------------------------------------------------------------------
# history equivalence: the vectorized loop vs the reference heap loop
# ---------------------------------------------------------------------------


def _history_equiv(setup, strategy_fn, jitter_sigma, n=100, total=130,
                   fed_extra=None):
    ds, task, tr0 = setup
    fleet = scale_fleet(make_fleet(3, 3, 2, M=4), n,
                        np.random.default_rng(7))
    kw = dict(rounds=1, local_epochs=1, steps_per_epoch=1, batch_size=4,
              eval_every=0, seed=0, jitter_sigma=jitter_sigma,
              **(fed_extra or {}))
    ref = AsyncFedRun.create(task, tr0, strategy_fn(buffer_size=8),
                             fleet, AsyncFedConfig(**kw))
    ref.run(ds, total_updates=total)
    vec = VectorizedAsyncFedRun.create(
        task, tr0, strategy_fn(buffer_size=8), fleet,
        AsyncFedConfig(grad_mode="dispatch", **kw))
    vec.run(ds, total_updates=total)

    h0, h1 = ref.history, vec.history
    assert len(h0["flush"]) == len(h1["flush"]) > 5
    for key in ("flush", "staleness_mean", "selected_frac", "sim_time_s"):
        np.testing.assert_array_equal(h0[key], h1[key], err_msg=key)
    np.testing.assert_allclose(h0["loss"], h1["loss"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h0["energy_j"], h1["energy_j"], rtol=1e-9)
    np.testing.assert_allclose(h0["upload_mb"], h1["upload_mb"], rtol=1e-9)
    assert ref.trace.completions == vec.trace.completions == total
    np.testing.assert_array_equal(ref.trace.per_client_updates,
                                  vec.trace.per_client_updates)
    for a, b in zip(jax.tree.leaves(ref.state.trainable),
                    jax.tree.leaves(vec.state.trainable)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_history_equivalence_cohort_agg(setup):
    """RELIEF strategy (cohort aggregation, divergence allocation): the
    vectorized runtime reproduces the heap loop's flush history at N=100."""
    _history_equiv(setup, async_relief, jitter_sigma=0.0)


def test_history_equivalence_fedavg_agg(setup):
    """FedBuff baseline (fedavg aggregation, full allocation) under compute
    jitter — distinct completion times exercise the windowed extraction's
    one-event-per-group path."""
    _history_equiv(setup, async_fedbuff, jitter_sigma=0.3)


@pytest.mark.parametrize("codec", ["none", "int8"])
def test_history_equivalence_under_faults(setup, codec):
    """Seeded fault injection keys every draw by (seed, client, dispatch
    ticket), never by event order or the runtime's jitter rng — so dropout,
    stalls, and targeted sign-flip corruption produce identical fault
    realizations in both runtimes and the flush histories (and final
    models) stay event-for-event identical, including through the int8
    uplink codec (corruption happens client-side, pre-quantization)."""
    fm = FaultModel(seed=3, byzantine_frac=0.3, corruption="sign_flip",
                    corruption_scale=5.0, dropout_prob=0.3, stall_prob=0.3,
                    stall_factor=4.0, target_modality=0)
    _history_equiv(setup, async_relief, jitter_sigma=0.2,
                   fed_extra={"faults": fm, "uplink_codec": codec})


# ---------------------------------------------------------------------------
# fleet scale: determinism, gradient decoupling, churn
# ---------------------------------------------------------------------------


def _vec_run(task, tr0, n, fed_kw, strategy_kw=None, total=2000, ds=None):
    fleet = scale_fleet(make_fleet(3, 3, 2, M=4), n,
                        np.random.default_rng(3))
    kw = dict(rounds=1, local_epochs=1, steps_per_epoch=1, batch_size=4,
              eval_every=0, seed=0)
    run = VectorizedAsyncFedRun.create(
        task, tr0, async_relief(**(strategy_kw or {"buffer_size": 64})),
        fleet, AsyncFedConfig(**(kw | fed_kw)))
    run.run(ds, total_updates=total)
    return run


def test_determinism_at_1e4(setup):
    """Same seed => bit-identical flush trace at N=10^4 (grad_mode="none":
    the pure system simulation the fleet benchmarks run)."""
    _, task, tr0 = setup
    runs = [_vec_run(task, tr0, 10_000, {"grad_mode": "none",
                                         "jitter_sigma": 0.2})
            for _ in range(2)]
    h0, h1 = runs[0].history, runs[1].history
    assert len(h0["flush"]) >= 30
    for key in ("flush", "sim_time_s", "staleness_mean", "energy_j",
                "selected_frac", "loss"):
        np.testing.assert_array_equal(h0[key], h1[key], err_msg=key)
    assert np.isnan(h0["loss"]).all()  # no gradient work was done
    np.testing.assert_array_equal(runs[0].fstate.updates,
                                  runs[1].fstate.updates)


def test_cohort_grad_mode_decouples_gradients(setup):
    """grad_mode="cohort" runs local updates only for flushed clients; the
    system-side trace is identical to grad_mode="none" and losses/model are
    finite and updated."""
    ds, task, tr0 = setup
    kw = {"buffer_size": 8}
    none = _vec_run(task, tr0, 200, {"grad_mode": "none"},
                    strategy_kw=kw, total=240)
    coh = _vec_run(task, tr0, 200, {"grad_mode": "cohort"},
                   strategy_kw=kw, total=240, ds=ds)
    for key in ("flush", "sim_time_s", "staleness_mean", "energy_j"):
        np.testing.assert_array_equal(none.history[key], coh.history[key],
                                      err_msg=key)
    assert np.isfinite(coh.history["loss"]).all()
    assert 0.0 <= coh.history["f1"][-1] <= 1.0
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(tr0),
                        jax.tree.leaves(coh.state.trainable)))
    assert changed


def test_churned_clients_stop_accruing(setup):
    """Departed clients lose in-flight work and their energy/update accounts
    freeze while the rest of the fleet keeps simulating."""
    _, task, tr0 = setup
    run = _vec_run(task, tr0, 500, {"grad_mode": "none", "churn_rate": 2.0},
                   total=1500)
    fs = run.fstate
    departed = np.nonzero(~fs.alive)[0]
    assert 0 < len(departed) < fs.N
    e0 = fs.energy_j[departed].copy()
    u0 = fs.updates[departed].copy()
    live_updates0 = fs.updates[fs.alive].sum()
    run.run(None, total_updates=500)  # keep simulating the survivors
    still_departed = departed[~fs.alive[departed]]  # arrival_rate=0: all
    np.testing.assert_array_equal(still_departed, departed)
    np.testing.assert_array_equal(fs.energy_j[departed], e0)
    np.testing.assert_array_equal(fs.updates[departed], u0)
    assert fs.updates[fs.alive].sum() > live_updates0


def test_churn_inflight_invariant(setup):
    """Regression: claimed-but-unabsorbed events of the current peek window
    (claim() sets t_next=inf up front) must not be mistaken for re-arrivals
    and double-dispatched. After any churn/arrival run the in-flight counter
    equals the number of scheduled completions, and every absorbed
    completion is accounted exactly once."""
    _, task, tr0 = setup
    for fed_kw in ({"churn_rate": 0.5},
                   {"churn_rate": 0.5, "arrival_rate": 0.5}):
        run = _vec_run(task, tr0, 500,
                       {"grad_mode": "none", "jitter_sigma": 0.1, **fed_kw},
                       total=1500)
        fs = run.fstate
        assert run.trace.completions == 1500, fed_kw
        assert fs.in_flight == int(np.isfinite(fs.t_next).sum()), fed_kw
        assert fs.in_flight <= int(fs.alive.sum()), fed_kw
        assert fs.updates.sum() == 1500, fed_kw


def test_throughput_1e5_clients_200_flushes(setup):
    """Acceptance floor: N=10^5 clients, >=200 server flushes, well under
    the 60s CI budget (measured ~2s; the 10x margin absorbs CI noise)."""
    import time
    _, task, tr0 = setup
    t0 = time.monotonic()
    run = _vec_run(task, tr0, 100_000,
                   {"grad_mode": "none", "jitter_sigma": 0.1},
                   total=64 * 200)
    wall = time.monotonic() - t0
    assert run.trace.flushes >= 200
    assert wall < 60.0, f"{wall:.1f}s for 200 flushes at N=1e5"


def test_vectorized_rejects_unsupported(setup):
    _, task, tr0 = setup
    fleet = make_fleet(2, 1, 1, M=4)
    with pytest.raises(ValueError, match="grad_mode"):
        VectorizedAsyncFedRun.create(task, tr0, async_relief(), fleet,
                                     AsyncFedConfig(grad_mode="bogus"))
    with pytest.raises(ValueError, match="dataset"):
        VectorizedAsyncFedRun.create(
            task, tr0, async_relief(), fleet,
            AsyncFedConfig(grad_mode="cohort")).run(None)
