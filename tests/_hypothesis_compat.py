"""Graceful degradation when ``hypothesis`` is absent (CI installs it via
``pip install -e .[dev]``; bare containers may not have it).

Importing ``given/settings/st`` from here instead of from hypothesis keeps
module collection alive everywhere: with hypothesis installed the real
objects are re-exported; without it, ``@given`` marks just the property
tests as skipped (``pytest.importorskip`` semantics, scoped per-test rather
than per-module so the plain unit tests in the same file still run).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the dep
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Placeholder for ``strategies``: any call returns None."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*a, **k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed (pip install -e .[dev])")(fn)

    def settings(*a, **k):
        return lambda fn: fn
