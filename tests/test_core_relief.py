"""Unit + property tests for the RELIEF core (mdlora, aggregation,
divergence, allocation) — the paper's Eqs. 1-8 and Props. 4-5."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import aggregation as AG
from repro.core import allocation as AL
from repro.core import divergence as DV
from repro.core import mdlora
from repro.core.tasks import MMTask
from repro.data import mm_config_for

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def cnn_task():
    cfg = mm_config_for("pamap2", backbone="cnn", d_feat=8, d_fused=32,
                        cnn_ch=(8, 16))
    return MMTask.create(cfg, KEY)


@pytest.fixture(scope="module")
def tx_task():
    cfg = mm_config_for("pamap2", backbone="transformer", d_feat=8,
                        d_fused=32, enc_layers=2, enc_d=16, enc_ff=32)
    return MMTask.create(cfg, KEY)


def _stack(tree, n, key):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: jax.tree.map(
        lambda x: jax.random.normal(k, x.shape, jnp.float32), tree))(keys)


# ---------------------------------------------------------------------------
# layout (Eq. 1 + Sec. III-B grouping)
# ---------------------------------------------------------------------------


def test_group_count_matches_paper_formula(cnn_task, tx_task):
    # G = M fusion blocks + 1 (B) + sum L_m encoder groups + L_H head groups
    for task, _ in (cnn_task, tx_task):
        lay = task.layout
        M = lay.n_modalities
        n_fusion = len(lay.group_ids(mdlora.KIND_FUSION_BLOCK))
        n_b = len(lay.group_ids(mdlora.KIND_FUSION_B))
        assert n_fusion == M == 4
        assert n_b == 1
        assert lay.G == n_fusion + n_b + len(lay.group_ids(
            mdlora.KIND_ENCODER)) + len(lay.group_ids(mdlora.KIND_HEAD))


def test_fusion_rows_partition_D(cnn_task):
    task, _ = cnn_task
    lay = task.layout
    D = task.cfg.D
    rg = lay.row_group_vector(D)
    # contiguous ordered blocks covering all rows exactly once
    assert len(rg) == D
    boundaries = [s for s, e, g in lay.fusion_rows] + [D]
    assert boundaries == sorted(boundaries)
    covered = np.zeros(D, bool)
    for s, e, g in lay.fusion_rows:
        assert not covered[s:e].any()
        covered[s:e] = True
    assert covered.all()


def test_accessible_and_mandatory(cnn_task):
    task, _ = cnn_task
    lay = task.layout
    mm = np.array([[1, 1, 0, 0], [1, 0, 0, 0], [1, 1, 1, 1]], bool)
    acc = lay.accessible(mm)
    man = lay.mandatory(mm)
    # mandatory set = owned fusion blocks only (paper IV-B2b)
    assert man.sum(1).tolist() == [2, 1, 4]
    assert (man <= acc).all()
    # B (size 0 in B1) and head accessibility
    head_ids = lay.group_ids(mdlora.KIND_HEAD)
    assert acc[:, head_ids].all()


def test_group_gate_roundtrip(cnn_task):
    task, tr = cnn_task
    lay = task.layout
    ones = mdlora.group_gate_tree(lay, tr, jnp.ones(lay.G))
    for a, b in zip(jax.tree.leaves(ones), jax.tree.leaves(tr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    zeros = mdlora.group_gate_tree(lay, tr, jnp.zeros(lay.G))
    assert all(float(jnp.max(jnp.abs(x))) == 0 for x in jax.tree.leaves(zeros))


def test_group_norms_partition_total(cnn_task):
    task, tr = cnn_task
    lay = task.layout
    gn = mdlora.group_norms(lay, tr)
    total = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
                for x in jax.tree.leaves(tr))
    np.testing.assert_allclose(float(jnp.sum(gn)), total, rtol=1e-5)


# ---------------------------------------------------------------------------
# aggregation (Eq. 3-4, Lemma 1, Theorem 2)
# ---------------------------------------------------------------------------


def test_cohort_equals_fedavg_when_homogeneous(cnn_task):
    """Theorem-2 sanity: with all clients owning all modalities and all
    groups trained, cohort-wise aggregation == FedAvg."""
    task, tr = cnn_task
    lay = task.layout
    N = 5
    deltas = _stack(tr, N, KEY)
    mm = jnp.ones((N, 4))
    trained = jnp.ones((N, lay.G)) * jnp.asarray(lay.sizes > 0)
    Wc = AG.cohort_weights(lay, trained, mm)
    Wf = AG.fedavg_weights(N, lay.G)
    agg_c = mdlora.weighted_combine(lay, deltas, Wc)
    agg_f = mdlora.weighted_combine(lay, deltas, Wf)
    for a, b in zip(jax.tree.leaves(agg_c), jax.tree.leaves(agg_f)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_absent_modality_never_pollutes_block(cnn_task):
    """Eq. 3: clients outside C~_m contribute nothing to block A_m."""
    task, tr = cnn_task
    lay = task.layout
    N = 4
    deltas = _stack(tr, N, KEY)
    mm = np.ones((N, 4)); mm[0, 2] = 0  # client 0 lacks modality 2 (mag)
    trained = lay.accessible(mm) & (lay.sizes > 0)
    W = AG.cohort_weights(lay, jnp.asarray(trained, jnp.float32),
                          jnp.asarray(mm, jnp.float32))
    agg = mdlora.weighted_combine(lay, deltas, W)
    # poison client 0's copy of the mag rows; aggregate must not change
    s, e, g = lay.fusion_rows[2]
    leaves, treedef = jax.tree_util.tree_flatten_with_path(deltas)
    poisoned = []
    for path, leaf in leaves:
        if mdlora.path_str(path) == lay.fusion_a_path:
            leaf = leaf.at[0, s:e].add(1e6)
        poisoned.append(leaf)
    agg2 = mdlora.weighted_combine(
        lay, jax.tree_util.tree_unflatten(treedef, poisoned), W)
    for a, b in zip(jax.tree.leaves(agg), jax.tree.leaves(agg2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_empty_cohort_freezes_block(cnn_task):
    task, tr = cnn_task
    lay = task.layout
    N = 3
    deltas = _stack(tr, N, KEY)
    mm = np.ones((N, 4)); mm[:, 3] = 0  # nobody owns hr
    trained = lay.accessible(mm) & (lay.sizes > 0)
    W = AG.cohort_weights(lay, jnp.asarray(trained, jnp.float32),
                          jnp.asarray(mm, jnp.float32))
    agg = mdlora.weighted_combine(lay, deltas, W)
    s, e, _ = lay.fusion_rows[3]
    leaves = jax.tree_util.tree_flatten_with_path(agg)[0]
    fusion = next(l for pth, l in leaves
                  if mdlora.path_str(pth) == lay.fusion_a_path)
    assert float(jnp.max(jnp.abs(fusion[s:e]))) == 0.0


def test_b_weighting_prefers_multimodal_clients(tx_task):
    """Eq. 4: w_n proportional to |M_n|/M among uploaders."""
    task, tr = tx_task
    lay = task.layout
    mm = jnp.asarray([[1, 1, 1, 1], [1, 0, 0, 0]], jnp.float32)
    trained = jnp.ones((2, lay.G))
    W = AG.cohort_weights(lay, trained, mm)
    b_gid = int(lay.group_ids(mdlora.KIND_FUSION_B)[0])
    np.testing.assert_allclose(np.asarray(W[:, b_gid]), [0.8, 0.2], rtol=1e-6)
    # head groups remain uniform
    h_gid = int(lay.group_ids(mdlora.KIND_HEAD)[0])
    np.testing.assert_allclose(np.asarray(W[:, h_gid]), [0.5, 0.5], rtol=1e-6)


@settings(deadline=None, max_examples=25)
@given(st.integers(2, 10), st.integers(1, 9), st.integers(0, 10**6))
def test_lemma1_decomposition_bounds(n, nc, seed):
    nc = min(nc, n)
    rng = np.random.default_rng(seed)
    deltas = jnp.asarray(rng.normal(size=(n, 6, 3)), jnp.float32)
    cohort = np.zeros(n, bool); cohort[:nc] = True
    # absent clients produce zero updates (Assumption 4, eps0 = 0)
    deltas = deltas * jnp.asarray(cohort, jnp.float32)[:, None, None]
    out = AG.lemma1_decomposition(deltas, cohort)
    assert float(out["error"]) <= float(out["bound"]) + 1e-5
    # with eps0=0, interference term vanishes and error = scaling bias exactly
    np.testing.assert_allclose(float(out["interference"]), 0.0, atol=1e-9)


# ---------------------------------------------------------------------------
# divergence (Eq. 5-6)
# ---------------------------------------------------------------------------


def test_group_divergence_matches_naive(cnn_task):
    task, tr = cnn_task
    lay = task.layout
    N = 5
    deltas = _stack(tr, N, KEY)
    cohort = jnp.asarray(np.random.default_rng(0).random((N, lay.G)) < 0.7,
                         jnp.float32)
    d = DV.group_divergence(lay, deltas, cohort)
    # naive per-group computation
    per_client = jax.vmap(lambda t: mdlora.group_norms(lay, t))
    for g in range(lay.G):
        c = np.asarray(cohort[:, g])
        if c.sum() == 0 or lay.sizes[g] == 0:
            assert float(d[g]) == 0.0
            continue
        Wg = jnp.zeros((N, lay.G)).at[:, g].set(cohort[:, g] / c.sum())
        mean_g = mdlora.weighted_combine(lay, deltas, Wg)
        dev = jax.tree.map(lambda x, m: x - m[None], deltas, mean_g)
        norms = per_client(dev)[:, g]
        want = float(jnp.sum(norms * cohort[:, g]) / c.sum())
        np.testing.assert_allclose(float(d[g]), want, rtol=1e-4)


def test_divergence_zero_for_identical_updates(cnn_task):
    task, tr = cnn_task
    lay = task.layout
    one = jax.tree.map(lambda x: jax.random.normal(KEY, x.shape), tr)
    deltas = jax.tree.map(lambda x: jnp.stack([x] * 4), one)
    d = DV.group_divergence(lay, deltas, jnp.ones((4, lay.G)))
    np.testing.assert_allclose(np.asarray(d), 0.0, atol=1e-6)


@settings(deadline=None, max_examples=20)
@given(st.floats(0.05, 0.95), st.floats(0.0, 1.0), st.integers(0, 1000))
def test_ema_bias_bound(gamma, delta_scale, seed):
    """Prop. 5 steady-state EMA bias <= delta*(1-gamma)/gamma (the CORRECTED
    Eq. 21 constant — see divergence.ema_bias_bound docstring)."""
    rng = np.random.default_rng(seed)
    R = 300
    d = np.cumsum(rng.uniform(-delta_scale, delta_scale, R)) + 5.0
    d = np.abs(d)
    delta_max = float(np.max(np.abs(np.diff(d)))) if R > 1 else 0.0
    dbar = d[0]
    biases = []
    for r in range(1, R):
        dbar = DV.ema_update(dbar, d[r], gamma)
        biases.append(abs(dbar - d[r]))
    bound = DV.ema_bias_bound(gamma, delta_max)
    assert max(biases[50:]) <= bound + 1e-9


def test_ema_paper_bound_is_violated_for_small_gamma():
    """Documents the Eq. 21 discrepancy: the paper's printed constant
    gamma*delta/(1-gamma)^2 is NOT an upper bound when gamma < 1/2 (the
    EMA lags a drifting signal by ~(1-gamma)/gamma steps)."""
    gamma, delta = 0.25, 1.0
    d = np.arange(300, dtype=float) * delta  # steady drift, |diff| = delta
    dbar = d[0]
    biases = []
    for r in range(1, 300):
        dbar = DV.ema_update(dbar, d[r], gamma)
        biases.append(abs(dbar - d[r]))
    paper = DV.ema_bias_bound_paper(gamma, delta)
    corrected = DV.ema_bias_bound(gamma, delta)
    assert max(biases[50:]) > paper  # the printed bound fails
    assert max(biases[50:]) <= corrected + 1e-9  # the corrected bound holds


# ---------------------------------------------------------------------------
# allocation (Eq. 7, Prop. 4)
# ---------------------------------------------------------------------------


def test_elastic_budgets_eq7():
    tau = np.array([1.0, 5.0, 50.0])
    k = AL.elastic_budgets(tau, t_star=10.0, t_overhead=0.0,
                           n_mandatory=np.array([4, 2, 1]),
                           g_max=np.array([19, 19, 19]))
    assert k.tolist() == [10, 2, 1]  # floor((10)/tau) with mandatory floor


def test_topk_respects_budget_and_mandatory():
    rng = np.random.default_rng(0)
    N, G = 6, 12
    dbar = rng.random(G)
    acc = rng.random((N, G)) < 0.8
    man = acc & (rng.random((N, G)) < 0.3)
    k = np.maximum(man.sum(1), rng.integers(1, G, N))
    S = AL.allocate_topk(dbar, acc, man, k)
    assert (S <= acc).all()
    assert (S >= man).all()
    assert (S.sum(1) <= k).all()
    # greedy optimality: selected non-mandatory groups have scores >= any
    # unselected accessible group
    for n in range(N):
        sel = S[n] & ~man[n]
        unsel = acc[n] & ~S[n]
        if sel.any() and unsel.any():
            assert dbar[sel].min() >= dbar[unsel].max() - 1e-12


@settings(deadline=None, max_examples=30)
@given(st.integers(2, 6), st.integers(0, 10**6))
def test_water_filling_is_kkt_optimal(m, seed):
    """Prop. 4: closed form beats any random feasible allocation."""
    rng = np.random.default_rng(seed)
    delta = rng.uniform(0.1, 10.0, m)
    K = rng.uniform(m, 10 * m)
    x_star, r_star = AL.water_filling(delta, K)
    np.testing.assert_allclose(x_star.sum(), K, rtol=1e-9)
    np.testing.assert_allclose(
        r_star, AL.weighted_cohort_residual(delta, x_star), rtol=1e-9)
    np.testing.assert_allclose(r_star, (np.sqrt(delta).sum())**2 / K,
                               rtol=1e-9)
    for _ in range(10):
        x = rng.dirichlet(np.ones(m)) * K
        assert AL.weighted_cohort_residual(delta, x) >= r_star - 1e-9
    # x* proportional to sqrt(delta)
    ratio = x_star / np.sqrt(delta)
    np.testing.assert_allclose(ratio, ratio[0], rtol=1e-9)


def test_topk_approximates_water_filling_rank_order():
    """Prop. 4 remark: greedy top-k is rank-preserving w.r.t. sqrt(delta)."""
    dbar = np.array([9.0, 4.0, 1.0, 0.25])
    acc = np.ones((1, 4), bool)
    man = np.zeros((1, 4), bool)
    for k in range(1, 5):
        S = AL.allocate_topk(dbar, acc, man, np.array([k]))
        assert S[0, :k].all() and not S[0, k:].any()


def test_solve_t_star_utilization_floor():
    tau = np.array([1.0, 13.0, 55.0])
    g_max = np.array([19, 19, 19])
    t = AL.solve_t_star(tau, 0.0, np.array([4, 2, 1]), g_max)
    # fastest device completes its full set within T*
    assert t >= 19.0 * 1.0 - 1e-6
    k = AL.elastic_budgets(tau, t, 0.0, np.array([4, 2, 1]), g_max)
    assert k[0] == 19  # fast device fully utilized
    assert k[2] >= 1  # mandatory floor
