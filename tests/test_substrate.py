"""Substrate tests: optimizer, checkpointing, compression, data, simulator."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_adam_converges_quadratic():
    from repro.optim import adam_init, adam_update

    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adam_init(params)
    for _ in range(500):
        grads = {"w": 2 * (params["w"] - target)}
        params, state = adam_update(params, grads, state, 0.05)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adam_moments_fp32_for_bf16_params():
    from repro.optim import adam_init, adam_update

    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = adam_init(params)
    assert state["m"]["w"].dtype == jnp.float32
    new_p, new_s = adam_update(params, {"w": jnp.ones(4, jnp.bfloat16)},
                               state, 1e-2)
    assert new_p["w"].dtype == jnp.bfloat16
    assert new_s["v"]["w"].dtype == jnp.float32


def test_schedules():
    from repro.optim import linear_warmup_cosine

    fn = linear_warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(fn(0)) == 0.0
    np.testing.assert_allclose(float(fn(10)), 1.0, rtol=1e-5)
    assert float(fn(110)) < 0.1


# ---------------------------------------------------------------------------
# checkpointing (fault tolerance)
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import CheckpointManager

    tree = {"a": jnp.arange(5, dtype=jnp.float32),
            "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    mgr.save(1, tree, {"round": 1})
    mgr.save(2, jax.tree.map(lambda x: x * 2, tree), {"round": 2})
    restored, meta = mgr.restore_latest(tree)
    assert meta["round"] == 2
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.arange(5) * 2)
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_retention_and_resume(tmp_path):
    from repro.checkpoint import CheckpointManager

    tree = {"w": jnp.zeros(3)}
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    for s in range(5):
        mgr.save(s, jax.tree.map(lambda x: x + s, tree))
    assert mgr.steps() == [3, 4]  # retention
    mgr2 = CheckpointManager(str(tmp_path / "ckpt"), keep=2)
    assert mgr2.latest_step() == 4  # resume across "process restart"


def test_checkpoint_structure_mismatch_rejected(tmp_path):
    from repro.checkpoint import restore_tree, save_tree

    save_tree(str(tmp_path / "x"), {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        restore_tree(str(tmp_path / "x"), {"a": jnp.ones(3),
                                           "b": jnp.ones(2)})


def test_engine_state_checkpoint_roundtrip(tmp_path):
    """Full FL server state survives a simulated preemption."""
    from repro.checkpoint import CheckpointManager
    from repro.core.engine import FedConfig, FedRun
    from repro.core.strategies import get_strategy
    from repro.core.tasks import MMTask
    from repro.data import make_har_dataset, mm_config_for
    from repro.sim import make_fleet

    ds = make_har_dataset("pamap2", windows_per_subject=40, seed=0)
    fleet = make_fleet(2, 1, 1, M=4)
    cfg = mm_config_for("pamap2", backbone="cnn", d_feat=8, d_fused=32,
                        cnn_ch=(8, 16))
    task, tr0 = MMTask.create(cfg, KEY)
    fed = FedConfig(rounds=2, local_epochs=1, steps_per_epoch=1,
                    batch_size=8, eval_every=2)
    run = FedRun.create(task, tr0, get_strategy("relief"), fleet, fed)
    run.round(ds)
    mgr = CheckpointManager(str(tmp_path / "fed"), keep=1)
    mgr.save(run.state.round, {"trainable": run.state.trainable},
             {"dbar": run.state.dbar.tolist(), "round": run.state.round})
    restored, meta = mgr.restore_latest({"trainable": run.state.trainable})
    assert meta["round"] == 1
    for a, b in zip(jax.tree.leaves(restored["trainable"]),
                    jax.tree.leaves(run.state.trainable)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=20)
@given(st.integers(0, 10**6))
def test_int8_quantization_error_bound(seed):
    from repro.dist import dequantize_int8, quantize_int8

    rng = np.random.default_rng(seed)
    tree = {"w": jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)}
    qt, sc = quantize_int8(tree)
    assert qt["w"].dtype == jnp.int8
    back = dequantize_int8(qt, sc)
    max_err = float(jnp.max(jnp.abs(back["w"] - tree["w"])))
    assert max_err <= float(sc["w"]) * 0.5 + 1e-7  # half-step rounding


def test_topk_error_feedback_accumulates():
    from repro.dist import topk_sparsify

    x = {"w": jnp.asarray([1.0, 0.1, 0.01, -2.0])}
    sparse, err = topk_sparsify(x, frac=0.25)  # keep 1 of 4
    assert int(jnp.sum(sparse["w"] != 0)) == 1
    assert float(sparse["w"][3]) == -2.0
    # error feedback: dropped mass resurfaces next round
    sparse2, err2 = topk_sparsify({"w": jnp.zeros(4)}, frac=0.25, error=err)
    assert float(sparse2["w"][0]) == 1.0


def test_compressed_size_accounting():
    from repro.dist import compressed_size_bytes

    tree = {"w": jnp.zeros((100,))}
    assert compressed_size_bytes(tree, "none") == 400
    assert compressed_size_bytes(tree, "int8") == 104
    assert compressed_size_bytes(tree, "topk", 0.1) == 80


def test_compressed_size_matches_actual_payload_bytes():
    """The comm-simulator accounting equals the bytes a real int8 payload
    occupies: q.nbytes per leaf + one fp32 scale per leaf."""
    from repro.dist import compressed_size_bytes, quantize_int8

    rng = np.random.default_rng(3)
    tree = {"a": jnp.asarray(rng.normal(size=(17, 5)), jnp.float32),
            "b": {"c": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}}
    qt, sc = quantize_int8(tree)
    actual = sum(np.asarray(q).nbytes for q in jax.tree.leaves(qt)) + \
        sum(np.asarray(s).nbytes for s in jax.tree.leaves(sc))
    assert compressed_size_bytes(tree, "int8") == actual
    assert compressed_size_bytes(tree, "none") == \
        sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 10**6))
def test_int8_error_feedback_telescopes(seed):
    """Over T rounds, sum(dequantized uploads) + final residual ==
    sum(raw updates): the EF stream is unbiased up to the carried residual."""
    from repro.dist import dequantize_int8, quantize_int8_ef

    rng = np.random.default_rng(seed)
    T = 6
    updates = [{"w": jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)}
               for _ in range(T)]
    err = None
    shipped = jnp.zeros((16, 4))
    for u in updates:
        qt, sc, err = quantize_int8_ef(u, err)
        shipped = shipped + dequantize_int8(qt, sc)["w"]
    total = sum(np.asarray(u["w"]) for u in updates)
    np.testing.assert_allclose(np.asarray(shipped + err["w"]), total,
                               rtol=1e-4, atol=1e-5)
    # the carried residual itself stays bounded by one quantization step
    assert float(jnp.max(jnp.abs(err["w"]))) <= float(sc["w"]) * 0.5 + 1e-7


@settings(deadline=None, max_examples=10)
@given(st.integers(0, 10**6))
def test_int8_stacked_matches_per_client(seed):
    """Stacked per-client quantization == quantizing each client's slice
    separately (scales leaves are [K], one symmetric scale per client)."""
    from repro.dist import (dequantize_int8_stacked, quantize_int8,
                            quantize_int8_stacked)

    rng = np.random.default_rng(seed)
    K = 4
    stack = {"w": jnp.asarray(rng.normal(size=(K, 6, 3)), jnp.float32)}
    qt, sc, resid = quantize_int8_stacked(stack)
    assert qt["w"].dtype == jnp.int8 and sc["w"].shape == (K,)
    for k in range(K):
        qk, sk = quantize_int8({"w": stack["w"][k]})
        np.testing.assert_array_equal(np.asarray(qt["w"][k]),
                                      np.asarray(qk["w"]))
        np.testing.assert_allclose(float(sc["w"][k]), float(sk["w"]),
                                   rtol=1e-6)
    # residual is exactly the round-trip error
    back = dequantize_int8_stacked(qt, sc)
    np.testing.assert_allclose(np.asarray(resid["w"]),
                               np.asarray(stack["w"] - back["w"]),
                               atol=1e-7)


def test_topk_error_feedback_telescopes_over_rounds():
    """Same telescoping contract for the top-k codec across many rounds."""
    from repro.dist import topk_sparsify

    rng = np.random.default_rng(0)
    T = 8
    updates = [{"w": jnp.asarray(rng.normal(size=(32,)), jnp.float32)}
               for _ in range(T)]
    err = None
    shipped = jnp.zeros(32)
    for u in updates:
        sparse, err = topk_sparsify(u, frac=0.25, error=err)
        shipped = shipped + sparse["w"]
    total = sum(np.asarray(u["w"]) for u in updates)
    np.testing.assert_allclose(np.asarray(shipped + err["w"]), total,
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_har_dataset_shapes_and_noniid():
    from repro.data import make_har_dataset

    ds = make_har_dataset("pamap2", windows_per_subject=60, seed=0)
    assert ds.n_subjects == 8
    assert ds.channels() == 10  # 3+3+3+1
    assert all(x.shape[1:] == (256, 10) for x in ds.train_x)
    # non-IID: per-subject class histograms differ
    h = [np.bincount(y, minlength=12) / len(y) for y in ds.train_y]
    dists = [np.abs(h[i] - h[j]).sum() for i in range(8) for j in range(i)]
    assert np.mean(dists) > 0.2

    ds2 = make_har_dataset("mhealth", windows_per_subject=40, seed=1)
    assert ds2.n_subjects == 10
    assert ds2.channels() == 11  # 3+3+3+2 (ECG 2 leads)


def test_har_classes_are_separable():
    """A class-conditional mean classifier beats chance by a wide margin —
    the synthetic signals carry learnable class structure."""
    from repro.data import make_har_dataset

    ds = make_har_dataset("pamap2", windows_per_subject=120, seed=0)

    def feats(xs):  # channel means + amplitudes (class-dependent)
        return np.concatenate([xs.mean(1), xs.std(1)], axis=-1)

    x = feats(np.concatenate(ds.train_x))
    y = np.concatenate(ds.train_y)
    xt = feats(np.concatenate(ds.test_x))
    yt = np.concatenate(ds.test_y)
    mus = np.stack([x[y == c].mean(0) if (y == c).any() else
                    np.zeros(x.shape[1]) for c in range(12)])
    pred = np.argmin(((xt[:, None] - mus[None]) ** 2).sum(-1), axis=1)
    assert (pred == yt).mean() > 0.25  # chance = 1/12


def test_token_stream_learnable():
    from repro.data import synthetic_token_batches

    batches = list(synthetic_token_batches(64, 4, 32, 3, seed=0))
    assert len(batches) == 3
    assert batches[0]["tokens"].shape == (4, 32)
    # order-1 structure: conditional entropy < marginal entropy
    toks = np.concatenate([b["tokens"].reshape(-1) for b in batches])


# ---------------------------------------------------------------------------
# simulator
# ---------------------------------------------------------------------------


def test_simulate_round_straggler_and_energy():
    from repro.sim import make_fleet
    from repro.sim.timing import simulate_round

    fleet = make_fleet(1, 1, 1, M=4)
    sel = np.ones(3, bool)
    fl = np.array([1e12, 1e12, 1e12])
    up = np.array([1e6, 1e6, 1e6])
    cost = simulate_round(fleet, sel, fl, np.zeros(3), up, t_overhead=0.0,
                          utilization=1.0)
    # round bound by slowest (5 TOPS) device
    expect = 1e12 / (5e12)
    assert abs(cost.round_time_s - (expect + 8 * 1e6 / 1e8)) < 0.05
    assert cost.fleet_energy_j > 0
    # idle time only for the fast devices
    assert cost.per_device_idle_s[0] > cost.per_device_idle_s[2] - 1e-9


def test_hetero_scaling():
    from repro.sim import make_fleet

    f10 = make_fleet(1, 1, 1, hetero_scale=10.0)
    f100 = make_fleet(1, 1, 1, hetero_scale=100.0)
    assert f10.tops[0] / f10.tops[2] == pytest.approx(10.0)
    assert f100.tops[0] / f100.tops[2] == pytest.approx(100.0)
