"""Scenario-matrix tests (sim/scenarios.py + data/registry.py +
core/strategies registry): generator determinism and exact ratios, provider
equality with the legacy loaders, from_scenario config builders, heap/vec
mask identity and streaming history parity, and the FedMFS selective-upload
byte invariant."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import strategies
from repro.data import (get_provider, make_har_dataset, mm_config_for,
                        provider_names)
from repro.sim import (FleetConfig, ScenarioSpec, build_fleet, get_scenario,
                       make_run, scenario_names, static_missing_mask,
                       streaming_schedule, tiered_missing_mask)
from repro.sim.scenarios import device_tiers, schedule_for

# every run in this file shares one model shape -> one jit compilation
_FAST = dict(windows_per_subject=40, local_epochs=1, steps_per_epoch=1,
             batch_size=8, eval_every=0)


# ---------------------------------------------------------------------------
# missing-modality generators
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("ratio", [0.1, 0.3, 0.5])
def test_static_mask_exact_ratio(ratio):
    base = np.ones((8, 4), bool)
    mask = static_missing_mask(base, ratio, seed=0)
    assert (base & ~mask).sum() == round(ratio * base.size)
    assert mask.sum(1).min() >= 1
    np.testing.assert_array_equal(mask, static_missing_mask(base, ratio, 0))
    if ratio > 0:
        assert not np.array_equal(mask, static_missing_mask(base, ratio, 1))


def test_static_mask_infeasible_raises():
    with pytest.raises(ValueError, match="cannot drop"):
        static_missing_mask(np.ones((4, 2), bool), 0.9, seed=0)


def test_tiered_mask_correlates_with_tier():
    fleet = build_fleet(ScenarioSpec("t", missing="tiered"))
    tiers = device_tiers(fleet)
    np.testing.assert_array_equal(tiers, [0, 0, 0, 1, 1, 1, 2, 2])
    base = np.ones((fleet.N, fleet.M), bool)
    mask = tiered_missing_mask(base, tiers, 0.3, seed=0)
    dropped = (base & ~mask).sum(1)
    # fastest tier drops nothing, slowest drops the most, everyone keeps >=1
    assert dropped[tiers == 0].max() == 0
    assert dropped[tiers == 2].min() > dropped[tiers == 0].max()
    assert mask.sum(1).min() >= 1
    np.testing.assert_array_equal(mask,
                                  tiered_missing_mask(base, tiers, 0.3, 0))


def test_streaming_schedule_pure_and_anchored():
    base = np.ones((8, 4), bool)
    base[0, 2:] = False  # partial possession intersects
    sched = streaming_schedule(base, ratio=0.3, period=40.0, seed=0)
    idx = np.array([5, 0, 3])
    for t in (0.0, 13.7, 999.9):
        full = sched.masks_at(t)
        np.testing.assert_array_equal(sched.masks_at(t, idx), full[idx])
        assert (full <= base).all()  # never exceeds possession
        rows = np.arange(8)
        np.testing.assert_array_equal(full[rows, sched.anchor],
                                      base[rows, sched.anchor])
        assert full.sum(1).min() >= 1
    # long-run on-fraction of non-anchor possessed pairs ~= duty
    ts = np.linspace(0.0, 4000.0, 2000)
    on = np.mean([sched.masks_at(t).astype(float) for t in ts], axis=0)
    free = base.copy()
    free[np.arange(8), sched.anchor] = False
    assert abs(on[free].mean() - sched.duty) < 0.05
    # same seed -> identical schedule arrays
    s2 = streaming_schedule(base, 0.3, 40.0, seed=0)
    np.testing.assert_array_equal(sched.period, s2.period)
    np.testing.assert_array_equal(sched.anchor, s2.anchor)


# ---------------------------------------------------------------------------
# registries: scenarios, strategies, providers
# ---------------------------------------------------------------------------


def test_scenario_library_and_overrides():
    assert {"paper", "static10", "static30", "static50", "tiered30",
            "stream30"} <= set(scenario_names())
    spec = get_scenario("static30", seed=7, missing_ratio=0.5)
    assert spec.missing == "static" and spec.missing_ratio == 0.5
    assert spec.seed == 7
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("nope")
    with pytest.raises(ValueError, match="missing must be"):
        ScenarioSpec("bad", missing="sometimes")
    with pytest.raises(ValueError, match="missing_ratio"):
        ScenarioSpec("bad", missing_ratio=1.0)


def test_strategy_registry():
    assert {"relief", "fedavg", "async_relief", "fedmfs_selective",
            "relief_selective"} <= set(strategies.names())
    assert strategies.get("relief") == strategies.relief()
    s = strategies.get("fedmfs_selective", comm_budget=0.25, buffer_size=8)
    assert s.selective and s.comm_budget == 0.25 and s.buffer_size == 8
    with pytest.raises(ValueError, match="unknown strategy"):
        strategies.get("nope")
    # deprecated alias keeps old call sites working
    assert strategies.get_strategy("fedavg") == strategies.get("fedavg")


def test_provider_matches_legacy_loader():
    assert {"pamap2", "mhealth", "ucf101_av"} <= set(provider_names())
    prov = get_provider("pamap2")
    ds_new = prov.build(seed=0, windows_per_subject=40)
    ds_old = make_har_dataset("pamap2", windows_per_subject=40, seed=0)
    for a, b in zip(ds_new.train_x, ds_old.train_x):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(ds_new.train_y, ds_old.train_y):
        np.testing.assert_array_equal(a, b)
    assert prov.mm_config("cnn", small=True) == mm_config_for(
        "pamap2", backbone="cnn", d_feat=16, d_fused=64, cnn_ch=(16, 32))


def test_ucf101_av_provider_builds():
    prov = get_provider("ucf101_av")
    assert [m.name for m in prov.modalities()] == ["video", "audio"]
    ds = prov.build(seed=0, windows_per_subject=20, n_clients=4)
    assert len(ds.train_x) == 4
    assert ds.train_x[0].shape[-1] == 12 + 2  # video + audio channels
    cfg = prov.mm_config("cnn", small=True)
    assert len(cfg.modalities) == 2


# ---------------------------------------------------------------------------
# from_scenario constructors
# ---------------------------------------------------------------------------


def test_from_scenario_configs():
    from repro.core.async_engine import AsyncFedConfig
    from repro.core.engine import FedConfig

    spec = get_scenario("static30", rounds=3, lr=2e-3, uplink_codec="int8",
                        jitter_sigma=0.2)
    afed = AsyncFedConfig.from_scenario(spec)
    assert afed.rounds == 3 and afed.lr == 2e-3
    assert afed.uplink_codec == "int8" and afed.jitter_sigma == 0.2
    assert afed.modality_schedule is None  # static, not streaming
    fed = FedConfig.from_scenario(spec, t_overhead=0.5)
    assert fed.rounds == 3 and fed.t_overhead == 0.5  # override wins

    stream = get_scenario("stream30")
    afed = AsyncFedConfig.from_scenario(stream)
    assert afed.modality_schedule is not None
    assert afed.modality_schedule.N == sum(stream.fleet)

    fleet = FleetConfig.from_scenario(spec)
    assert fleet.N == sum(spec.fleet)
    miss = (~fleet.modality_mask).sum()
    assert miss == round(spec.missing_ratio * fleet.N * fleet.M)


def test_fleet_scaling_is_seeded():
    spec = get_scenario("static30", n_clients=50)
    f1, f2 = build_fleet(spec), build_fleet(spec)
    assert f1.N == 50
    np.testing.assert_array_equal(f1.modality_mask, f2.modality_mask)
    np.testing.assert_array_equal(f1.tops, f2.tops)


# ---------------------------------------------------------------------------
# cross-runtime identity and parity
# ---------------------------------------------------------------------------


def test_same_seed_same_masks_across_runtimes():
    """Both runtimes constructed from one spec see identical possession
    masks and (for streaming) identical schedules — masks are a function of
    the spec, never of the runtime."""
    spec = get_scenario("static30", **_FAST)
    heap_run, sc_h = make_run(spec)
    vec_run, sc_v = make_run(spec, vectorized=True)
    np.testing.assert_array_equal(sc_h.fleet.modality_mask,
                                  sc_v.fleet.modality_mask)
    stream = get_scenario("stream30", **_FAST)
    sh = schedule_for(stream)
    sv = schedule_for(stream)
    np.testing.assert_array_equal(sh.period, sv.period)
    np.testing.assert_array_equal(sh.phase, sv.phase)
    np.testing.assert_array_equal(sh.anchor, sv.anchor)


def test_streaming_history_parity_heap_vs_vec():
    """Time-varying masks keep the two async runtimes event-for-event
    equivalent: live masks are a pure function of (seed, client, sim-time),
    and both runtimes dispatch the identical (time, client) sequence."""
    spec = get_scenario("stream30", total_updates=24, **_FAST)
    heap_run, sc = make_run(spec)
    h0 = heap_run.run(sc.dataset)
    vec_run, sc2 = make_run(spec, vectorized=True)
    h1 = vec_run.run(sc2.dataset)
    assert len(h0["flush"]) == len(h1["flush"]) >= 4
    for key in ("flush", "staleness_mean", "selected_frac", "sim_time_s"):
        np.testing.assert_array_equal(h0[key], h1[key], err_msg=key)
    np.testing.assert_allclose(h0["loss"], h1["loss"], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(h0["upload_mb"], h1["upload_mb"], rtol=1e-9)


def test_streaming_determinism_under_churn():
    """Churn reshuffles which clients are up, but the masks stay pure in
    (seed, client, time): the same spec run twice through the vectorized
    runtime under churn produces bit-identical histories."""
    spec = get_scenario("stream30", n_clients=200, grad_mode="none",
                        jitter_sigma=0.1, total_updates=400, **_FAST)
    runs = []
    for _ in range(2):
        run, _ = make_run(spec, vectorized=True, churn_rate=0.5,
                          arrival_rate=0.5)
        run.run(None)
        runs.append(run)
    h0, h1 = runs[0].history, runs[1].history
    assert len(h0["flush"]) >= 10
    for key in ("flush", "sim_time_s", "staleness_mean", "selected_frac",
                "energy_j"):
        np.testing.assert_array_equal(h0[key], h1[key], err_msg=key)
    np.testing.assert_array_equal(runs[0].fstate.updates,
                                  runs[1].fstate.updates)
    assert (~runs[0].fstate.alive).any()  # churn actually happened


# ---------------------------------------------------------------------------
# FedMFS selective communication
# ---------------------------------------------------------------------------


def test_selective_uploads_fewer_bytes():
    """fedmfs_selective is async_accessible plus the selective uploader:
    training is identical (selection happens at upload, not compute), so
    for the same number of absorbed updates the byte total must come in
    well under the non-selective twin — and the shorter comm cycles may
    only ever *accelerate* the simulated clock, never slow it."""
    spec = get_scenario("static30", total_updates=16,
                        strategy="async_accessible", **_FAST)
    ref_run, sc = make_run(spec)
    ref_run.run(sc.dataset)
    sel_spec = dataclasses.replace(spec, strategy="fedmfs_selective",
                                   strategy_args=(("comm_budget", 0.5),))
    sel_run, sc2 = make_run(sel_spec)
    sel_run.run(sc2.dataset)
    assert sel_run.trace.completions == ref_run.trace.completions == 16
    # at budget 0.5 the per-update upload is ~half the trained set (plus
    # the top-1 guarantee): require a real margin, not just "less"
    assert sel_run.trace.upload_mb < 0.75 * ref_run.trace.upload_mb
    assert sel_run.state.sim_time <= ref_run.state.sim_time
    assert np.isfinite(sel_run.history["loss"]).all()


def test_selective_respects_budget_per_client():
    """Every flushed upload outside the top-1 guarantee fits the byte
    budget: uploaded sizes <= comm_budget * trained sizes + largest block."""
    from repro.core.async_engine import _selective_upload

    run, sc = make_run(get_scenario("static30", **_FAST))
    layout = run.task.layout
    sizes = np.asarray(layout.sizes, np.float64)
    rng = np.random.default_rng(0)
    S = layout.accessible(sc.fleet.modality_mask)
    deltas = jax.tree.map(
        lambda x: jax.numpy.asarray(
            rng.standard_normal((sc.fleet.N,) + np.shape(x)), jax.numpy.float32),
        run.state.trainable)
    S_up = _selective_upload(layout, deltas, S, budget=0.5)
    assert (S_up <= S).all()
    assert (S_up.sum(1) >= 1).all()  # top-1 always ships
    up, tr = S_up @ sizes, S @ sizes
    assert (up <= 0.5 * tr + sizes.max() + 1e-9).all()
