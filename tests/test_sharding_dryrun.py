"""Sharding-spec unit tests + a miniature dry-run in a subprocess (8 fake
host devices, so the main test process keeps its single real device)."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import base

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_partition_specs_shapes_divisible():
    """Every spec produced for the production mesh must evenly divide its
    dim (jit input requirement) for all archs and both step kinds."""
    from jax.sharding import PartitionSpec as P

    import repro.dist.sharding as SH
    from repro.launch import step_fns as SF

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    mesh = FakeMesh()
    for arch in base.list_archs():
        cfg = base.get_arch(arch).FULL
        params = SF.abstract_params(cfg)
        for kind in ("train", "serve"):
            strat = SH.pick_strategy(cfg, kind)
            specs = SH.param_specs(cfg, params, mesh, train=(kind == "train"),
                                   strategy=strat)
            flat_p = jax.tree_util.tree_flatten(params)[0]
            flat_s = jax.tree.leaves(specs,
                                     is_leaf=lambda x: isinstance(x, P))
            for leaf, spec in zip(flat_p, flat_s):
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    if ax is None:
                        continue
                    axes = (ax,) if isinstance(ax, str) else ax
                    size = int(np.prod([mesh.shape[a] for a in axes]))
                    assert dim % size == 0, (arch, kind, leaf.shape, spec)


def test_act_hint_noop_without_mesh():
    import jax.numpy as jnp

    from repro.dist.sharding import act_hint, set_activation_mesh

    set_activation_mesh(None)
    x = jnp.ones((4, 8))
    assert act_hint(x, "batch", "model") is x


def test_strategy_selection():
    from repro.dist.sharding import pick_strategy

    assert pick_strategy(base.get_arch("phi3-medium-14b").FULL,
                         "train") == "fsdp"
    assert pick_strategy(base.get_arch("phi3-medium-14b").FULL,
                         "decode") == "tp"
    assert pick_strategy(base.get_arch("mixtral-8x7b").FULL, "train") == "tp"
    assert pick_strategy(base.get_arch("mamba2-1.3b").FULL,
                         "train") == "replicated"


@pytest.mark.slow
def test_miniature_dryrun_subprocess(tmp_path):
    """Lower+compile a smoke arch on an 8-device fake mesh end to end —
    validates the whole dryrun pipeline fast."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, %r)
import dataclasses, json
import jax
from repro.configs import base
from repro.dist import sharding as SH
from repro.launch import step_fns as SF
from repro.launch import roofline as RL

mesh = jax.make_mesh((4, 2), ("data", "model"))
mod = base.get_arch("granite-3-8b")
cfg = dataclasses.replace(mod.SMOKE, n_layers=2, scan_layers=False)
shape = base.ShapeConfig("t", 64, 8, "train")
SH.set_activation_mesh(mesh, tp=False,
                       batch_axes=("data", "model"))
params = SF.abstract_params(cfg)
pspec = SH.param_specs(cfg, params, mesh, strategy="fsdp")
tr, _ = SF.split_trainable(params, "lora")
opt = SF.abstract_opt_state(tr)
ospec = SH.opt_state_specs(pspec["lora"], opt, mesh)
batch = base.lm_input_specs(cfg, shape)
bspec = SH.batch_specs(batch, mesh, cfg, "fsdp")
sh = lambda t: SH.to_named(mesh, t)
fn = SF.make_train_step(cfg)
with mesh:
    compiled = jax.jit(fn, in_shardings=(sh(pspec), sh(ospec), sh(bspec))
                       ).lower(params, opt, batch).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jaxlib<0.4.38: one entry per device
        ca = ca[0] if ca else {}
    coll = RL.parse_collectives(compiled.as_text())
print(json.dumps({"flops": ca.get("flops", 0),
                  "colls": sum(coll.counts.values())}))
""" % SRC  # noqa: UP031 — %r-quoting a path into a code template; an f-string would need every brace below escaped
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["flops"] > 0


def test_roofline_collective_parser():
    from repro.launch.roofline import parse_collectives, _type_bytes

    assert _type_bytes("bf16[4,8]") == 64
    assert _type_bytes("(f32[2,2], f32[4])") == 32
    hlo = """
ENTRY main {
  %x = bf16[16,128]{1,0} all-gather(%a), replica_groups={}
  %y = f32[8,8]{1,0} all-reduce(%b), to_apply=%add
}
body {
  %z = bf16[4,4]{1,0} reduce-scatter(%c)
}
"""
    st = parse_collectives(hlo)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1}
    assert st.bytes_entry == 16 * 128 * 2 + 8 * 8 * 4 * 2  # AR counted 2x
    assert st.bytes_scanned == 4 * 4 * 2
    assert st.total(scan_steps=3) == st.bytes_entry + 3 * st.bytes_scanned


def test_input_specs_all_cells_shaped():
    """Every supported (arch x shape) produces well-formed input specs."""
    for arch in base.list_archs():
        mod = base.get_arch(arch)
        for shape in base.ALL_SHAPES:
            if not base.supports(mod.FULL, shape):
                continue
            specs = mod.input_specs(shape)
            for k, v in specs.items():
                assert hasattr(v, "shape") and hasattr(v, "dtype"), (arch, k)
            if shape.kind == "train":
                assert "labels" in specs
            if shape.kind == "decode":
                assert specs["token"].shape[0] == shape.global_batch
