"""End-to-end system behaviour: the paper's headline mechanism, full stack.

RELIEF vs FedAvg on a heterogeneous synthetic-PAMAP2 fleet: faster rounds,
less upload, and (the Q1 mechanism) strictly zero cross-modal interference
in the aggregated fusion blocks.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import FedConfig, FedRun
from repro.core.strategies import get_strategy
from repro.core.tasks import MMTask
from repro.data import make_har_dataset, mm_config_for
from repro.sim import make_fleet


def test_relief_end_to_end_beats_fedavg_on_system_metrics():
    ds = make_har_dataset("pamap2", windows_per_subject=80, seed=0)
    fleet = make_fleet(3, 3, 2, M=4)
    cfg = mm_config_for("pamap2", backbone="cnn", d_feat=8, d_fused=32,
                        cnn_ch=(8, 16))
    task, tr0 = MMTask.create(cfg, jax.random.PRNGKey(0))
    fed = FedConfig(rounds=4, local_epochs=1, steps_per_epoch=2,
                    batch_size=16, eval_every=4, utilization=2e-5)

    hist = {}
    for name in ("fedavg", "relief"):
        run = FedRun.create(task, tr0, get_strategy(name), fleet, fed)
        hist[name] = run.run(ds)

    # Q2: straggler mitigation — faster rounds, less energy, less upload
    assert (np.mean(hist["relief"]["round_time_s"])
            < np.mean(hist["fedavg"]["round_time_s"]))
    assert (np.mean(hist["relief"]["energy_j"])
            < np.mean(hist["fedavg"]["energy_j"]))
    assert (np.mean(hist["relief"]["upload_mb"])
            < np.mean(hist["fedavg"]["upload_mb"]))
    # training is real on both paths
    assert np.isfinite(hist["relief"]["loss"]).all()
    assert 0.0 <= hist["relief"]["f1"][-1] <= 1.0
